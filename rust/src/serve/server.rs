//! TCP server: dual-plane dispatch over one listener. Each message is
//! sniffed by its first byte without consuming it — `{` (or anything
//! line-like) is a JSON v1/v2 line, the magic `0xB3` is a binary v3 frame
//! (`protocol::frame`); both planes can interleave freely on one
//! connection. Requests materialize synthetic workloads, thread the
//! operand-handle lifecycle (`put_a`/`drop_a`/`list_a` and `spdm` by
//! handle) through the coordinator's converted-operand store, and drive
//! the coordinator. Both planes decode into the same `Request` and run
//! through the same dispatch core, so the encoding can change wire cost
//! but never results.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::protocol::{
    frame, parse_request, render_response, APayload, BPayload, HandleInfo, Payload, Request,
    Response,
};
use crate::coordinator::{Coordinator, OperandId, SpdmRequest};
use crate::gen;
use crate::json::{self, Value};
use crate::ndarray::Mat;
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:7077".into() }
    }
}

impl ServerConfig {
    /// Bind to an OS-assigned ephemeral port. Tests and examples must use
    /// this (never the fixed default) so parallel runs cannot collide; read
    /// the actual address back via [`Server::local_addr`].
    pub fn ephemeral() -> Self {
        ServerConfig { addr: "127.0.0.1:0".into() }
    }
}

/// The serving front end. Owns the listener; the coordinator is shared.
pub struct Server {
    listener: TcpListener,
    coordinator: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn bind(cfg: &ServerConfig, coordinator: Arc<Coordinator>) -> std::io::Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        Ok(Server { listener, coordinator, stop: Arc::new(AtomicBool::new(false)) })
    }

    /// The bound address (useful when binding port 0 in tests).
    pub fn local_addr(&self) -> std::io::Result<std::net::SocketAddr> {
        self.listener.local_addr()
    }

    /// Handle for requesting shutdown from another thread.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Accept loop; returns when a shutdown request arrives or `stop` is set.
    /// Connections are handled on their own threads; jobs funnel into the
    /// shared coordinator whose queue provides the backpressure.
    pub fn run(self) -> std::io::Result<()> {
        self.listener.set_nonblocking(true)?;
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _addr)) => {
                    let coord = Arc::clone(&self.coordinator);
                    let stop = Arc::clone(&self.stop);
                    conns.push(std::thread::spawn(move || {
                        let _ = handle_connection(stream, &coord, &stop);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
        }
        for c in conns {
            let _ = c.join();
        }
        Ok(())
    }
}

/// True for the io::ErrorKinds the read timeout produces — a tick to
/// re-check `stop`, not a connection failure. (Shared with the cluster
/// router, whose front-end loop is the same sniff-and-dispatch.)
pub(crate) fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut
            | std::io::ErrorKind::Interrupted
    )
}

/// Wait for the next byte and return it **without consuming it** (the
/// first-byte sniff). `Ok(None)` on EOF or stop.
pub(crate) fn peek_byte(
    reader: &mut BufReader<TcpStream>,
    stop: &AtomicBool,
) -> std::io::Result<Option<u8>> {
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(None);
        }
        match reader.fill_buf() {
            Ok(buf) if buf.is_empty() => return Ok(None), // EOF
            Ok(buf) => return Ok(Some(buf[0])),
            Err(e) if is_timeout(&e) => continue, // timeout tick
            Err(e) => return Err(e),
        }
    }
}

/// `read_exact` that honors the read timeout so an idle mid-frame
/// connection still re-checks `stop`. `Ok(false)` on EOF or stop.
pub(crate) fn read_exact_interruptible(
    reader: &mut BufReader<TcpStream>,
    buf: &mut [u8],
    stop: &AtomicBool,
) -> std::io::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        if stop.load(Ordering::SeqCst) {
            return Ok(false);
        }
        match reader.read(&mut buf[filled..]) {
            Ok(0) => return Ok(false), // EOF mid-frame
            Ok(k) => filled += k,
            Err(e) if is_timeout(&e) => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

fn handle_connection(
    stream: TcpStream,
    coord: &Coordinator,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    // Read timeout so idle connections re-check `stop` — otherwise a client
    // holding an open connection would pin this handler past shutdown.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    // Reused frame-payload buffer: one allocation reaches steady state for
    // a connection sending same-shaped frames.
    let mut payload: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        // Sniff the first byte of the next message — unless a partial JSON
        // line is pending from a read timeout, which must keep draining as
        // a line (its next byte is mid-line, not a message start).
        let first = if line.is_empty() {
            match peek_byte(&mut reader, stop)? {
                Some(b) => b,
                None => return Ok(()),
            }
        } else {
            b'{'
        };
        if first == frame::MAGIC {
            // Binary v3 frame: fixed header, then the length-prefixed
            // payload, then one dispatch producing one reply frame.
            let mut hdr = [0u8; frame::HEADER_LEN];
            if !read_exact_interruptible(&mut reader, &mut hdr, stop)? {
                return Ok(());
            }
            let h = match frame::parse_header(&hdr) {
                Ok(h) => h,
                Err(e) => {
                    // A bad header means the stream cannot be resynced:
                    // reply with a typed error frame and close.
                    writer.write_all(&frame::encode_resp_err(0, &e))?;
                    writer.flush()?;
                    return Ok(());
                }
            };
            payload.resize(h.len, 0);
            if !read_exact_interruptible(&mut reader, &mut payload, stop)? {
                return Ok(());
            }
            let reply = dispatch_frame(h.ftype, &payload, coord, stop);
            writer.write_all(&reply)?;
            writer.flush()?;
        } else {
            // JSON plane: `{` starts a v1/v2 line; any other junk also
            // flows here and earns a JSON parse-error reply.
            // NB: on timeout, read_line may have appended a *partial*
            // line; keep the buffer and let the next call complete it.
            match reader.read_line(&mut line) {
                Ok(0) => return Ok(()), // EOF: client closed
                Ok(_) => {
                    let request = line.trim().to_string();
                    line.clear();
                    if request.is_empty() {
                        continue;
                    }
                    let resp = dispatch(&request, coord, stop);
                    writer.write_all(render_response(&resp).as_bytes())?;
                    writer.write_all(b"\n")?;
                    writer.flush()?;
                }
                Err(e) if is_timeout(&e) => {
                    continue; // timeout tick: loop to re-check stop
                }
                Err(e) => return Err(e),
            }
        }
    }
}

/// Turn one request line into a response (pure-ish; unit tested directly).
///
/// Coordinator-side failures — including a submit racing shutdown, which
/// `Coordinator::run_sync` surfaces as a failed `SpdmResponse` rather than
/// a panic — come back as `{"ok":false,"error":…}` JSON replies.
pub fn dispatch(line: &str, coord: &Coordinator, stop: &AtomicBool) -> Response {
    let req = match parse_request(line) {
        Ok(r) => r,
        Err(e) => {
            // Best-effort id recovery so parse-level rejections (bad
            // payload values, unknown patterns, …) still correlate to the
            // client's request instead of id 0.
            let id = json::parse(line)
                .ok()
                .and_then(|v| v.get("id").and_then(Value::as_u64))
                .unwrap_or(0);
            return Response { id, ok: false, error: Some(e), ..Default::default() };
        }
    };
    dispatch_request(req, coord, stop).0
}

/// Turn one binary v3 request frame into one reply frame. The same
/// dispatch core as the JSON plane — only the encoding differs, plus the
/// binary-only `want_c` option (the reply frame carries the full C matrix
/// as raw LE f32, which JSON never does because an n² text render would
/// put the parse cost right back on the wire).
pub fn dispatch_frame(ftype: u8, payload: &[u8], coord: &Coordinator, stop: &AtomicBool) -> Vec<u8> {
    let (req, want_c) = match frame::decode_request(ftype, payload) {
        Ok(x) => x,
        Err(e) => {
            // Typed error frame, correlated to the request when the
            // payload prefix still yields an id (the binary twin of the
            // JSON dispatcher's id recovery).
            return frame::encode_resp_err(frame::request_id_hint(payload), &e);
        }
    };
    let is_ping = matches!(req, Request::Ping { .. });
    let is_put = matches!(req, Request::PutA { .. });
    let (resp, c) = dispatch_request(req, coord, stop);
    if !resp.ok {
        frame::encode_resp_err(resp.id, resp.error.as_deref().unwrap_or("request failed"))
    } else if is_ping {
        frame::encode_resp_pong(resp.id)
    } else if is_put {
        frame::encode_resp_put_a(&resp)
    } else {
        frame::encode_resp_spdm(&resp, if want_c { c.as_ref() } else { None })
    }
}

/// The shared dispatch core both planes run through. Returns the response
/// plus the computed C matrix for spdm requests (the JSON plane drops it —
/// its replies carry only the checksum; the binary plane returns it when
/// the client set `want_c`).
fn dispatch_request(
    req: Request,
    coord: &Coordinator,
    stop: &AtomicBool,
) -> (Response, Option<Mat>) {
    match req {
        Request::Ping { id } => (Response { id, ok: true, ..Default::default() }, None),
        Request::Shutdown { id } => {
            stop.store(true, Ordering::SeqCst);
            (Response { id, ok: true, ..Default::default() }, None)
        }
        Request::Metrics { id } => (
            Response {
                id,
                ok: true,
                metrics: Some(coord.snapshot().render()),
                ..Default::default()
            },
            None,
        ),
        // Structured stats: the `metrics` field carries the JSON-encoded
        // snapshot (incl. batch_hist, conversions_total, store gauges, the
        // admission-window counters, and the adaptive
        // route_flips/explorations counters).
        Request::Stats { id } => (
            Response {
                id,
                ok: true,
                metrics: Some(coord.snapshot().to_json()),
                ..Default::default()
            },
            None,
        ),
        // Adaptive routing introspection: the routing table + per-entry
        // measured estimates, as one JSON document in `routing`.
        Request::Explain { id } => (
            Response {
                id,
                ok: true,
                routing: Some(coord.explain_json()),
                ..Default::default()
            },
            None,
        ),
        // v2: register A once — the reply carries the handle plus the
        // resolved routing (algo/artifact/n_exec/reason) and the
        // registration EO, so clients can introspect what handle traffic
        // will run.
        // The tenant rides the registration: its token bucket gates
        // admission (`RATE_LIMITED: …`) and its store slice bounds
        // residency (`QUOTA_EXCEEDED: …`) — both come back as ordinary
        // error replies and the connection stays open.
        Request::PutA { id, n, payload, algo, tenant } => {
            let a = match materialize_a(n, payload) {
                Ok(a) => a,
                Err(e) => {
                    return (
                        Response { id, ok: false, error: Some(e), ..Default::default() },
                        None,
                    )
                }
            };
            let resp = match coord.put_a_for(&tenant, a, algo) {
                Ok(entry) => Response {
                    id,
                    ok: true,
                    a_handle: Some(entry.handle.0),
                    algo: Some(entry.plan.algo.as_str().to_string()),
                    artifact: Some(entry.plan.artifact.clone()),
                    n_exec: Some(entry.plan.n_exec),
                    convert_ms: Some(entry.convert_s * 1e3),
                    reason: Some(entry.plan.reason.to_string()),
                    ..Default::default()
                },
                Err(e) => Response { id, ok: false, error: Some(e), ..Default::default() },
            };
            (resp, None)
        }
        Request::DropA { id, a_handle } => {
            let resp = if coord.drop_a(OperandId(a_handle)) {
                Response { id, ok: true, a_handle: Some(a_handle), ..Default::default() }
            } else {
                Response {
                    id,
                    ok: false,
                    error: Some(format!("unknown operand handle a#{a_handle}")),
                    ..Default::default()
                }
            };
            (resp, None)
        }
        Request::ListA { id } => {
            let handles = coord
                .list_a()
                .into_iter()
                .map(|s| HandleInfo {
                    a_handle: s.handle.0,
                    n: s.n,
                    nnz: s.nnz,
                    algo: s.algo.as_str().to_string(),
                    artifact: s.artifact,
                    bytes: s.bytes,
                    tier: s.tier.to_string(),
                    last_used_seq: s.last_used_seq,
                })
                .collect();
            (Response { id, ok: true, handles: Some(handles), ..Default::default() }, None)
        }
        Request::Spdm { id, n, payload, algo, verify, tenant } => {
            let mut sreq = match build_spdm(coord, id, n, payload) {
                Ok(r) => r,
                Err(e) => {
                    return (
                        Response { id, ok: false, error: Some(e), ..Default::default() },
                        None,
                    )
                }
            };
            sreq.algo_hint = algo;
            sreq.verify = verify;
            // Tenant tag drives lane/bucket/slice selection in the
            // coordinator; a rate-limited submit comes back through
            // `run_sync` as a failed response → typed error reply, the
            // connection survives.
            sreq.tenant = tenant;
            let a_handle = sreq.a.handle().map(|h| h.0);
            let mut resp = coord.run_sync(sreq);
            if let Some(err) = resp.error {
                return (
                    Response { id, ok: false, error: Some(err), ..Default::default() },
                    None,
                );
            }
            let c = resp.c.take();
            let checksum = c.as_ref().map(|c| c.data.iter().map(|x| *x as f64).sum());
            (
                Response {
                    id,
                    ok: true,
                    algo: Some(resp.algo.as_str().to_string()),
                    artifact: Some(resp.artifact),
                    n_exec: Some(resp.n_exec),
                    convert_ms: Some(resp.convert_s * 1e3),
                    kernel_ms: Some(resp.kernel_s * 1e3),
                    total_ms: Some(resp.total_s * 1e3),
                    verified: resp.verified,
                    checksum,
                    a_handle,
                    ..Default::default()
                },
                c,
            )
        }
    }
}

/// Turn a parsed spdm payload into the library request: inline/synthetic
/// payloads materialize both operands (v1); handle payloads resolve the
/// registered operand's size, materialize only B, and reference A.
/// Takes the payload **by value**: inline operand vectors move straight
/// into the `Mat`s the pipeline owns — the protocol decode (text or
/// binary) is the last copy either plane makes.
fn build_spdm(
    coord: &Coordinator,
    id: u64,
    n: usize,
    payload: Payload,
) -> Result<SpdmRequest, String> {
    match payload {
        Payload::Handle { a_handle, b } => {
            let h = OperandId(a_handle);
            let dims = coord
                .operand_dims(h)
                .ok_or_else(|| format!("unknown operand handle a#{a_handle}"))?;
            if n != 0 && n != dims {
                return Err(format!("n {n} does not match registered operand size {dims}"));
            }
            let b = match b {
                BPayload::Inline(data) => {
                    if data.len() != dims * dims {
                        return Err(format!(
                            "inline b size {} != registered operand n²={}",
                            data.len(),
                            dims * dims
                        ));
                    }
                    Mat::from_vec(dims, dims, data)
                }
                BPayload::Synthetic { seed } => {
                    let mut rng = Rng::new(seed);
                    Mat::randn(dims, dims, &mut rng)
                }
            };
            Ok(SpdmRequest::for_handle(id, h, b))
        }
        _ => {
            let (a, b) = materialize(n, payload)?;
            Ok(SpdmRequest::new(id, a, b))
        }
    }
}

/// Materialize a `put_a` payload. The pattern name was already validated
/// at parse time (`synthetic_params`); the check here is defense in depth
/// at the trust boundary — a server answers with an error, never a panic.
/// By value: an inline operand moves into the store without another copy.
/// (Shared with the cluster router, which materializes synthetic `put_a`
/// payloads to route them by content signature.)
pub(crate) fn materialize_a(n: usize, payload: APayload) -> Result<Mat, String> {
    match payload {
        APayload::Inline { a } => Ok(Mat::from_vec(n, n, a)),
        APayload::Synthetic { sparsity, pattern, seed } => {
            let pat = gen::Pattern::from_name(&pattern)
                .ok_or_else(|| format!("unknown pattern {pattern}"))?;
            let mut rng = Rng::new(seed);
            Ok(gen::generate(pat, n, sparsity, &mut rng))
        }
    }
}

fn materialize(n: usize, payload: Payload) -> Result<(Mat, Mat), String> {
    match payload {
        Payload::Inline { a, b } => Ok((Mat::from_vec(n, n, a), Mat::from_vec(n, n, b))),
        Payload::Synthetic { sparsity, pattern, seed } => {
            let pat = gen::Pattern::from_name(&pattern)
                .ok_or_else(|| format!("unknown pattern {pattern}"))?;
            let mut rng = Rng::new(seed);
            let a = gen::generate(pat, n, sparsity, &mut rng);
            let b = Mat::randn(n, n, &mut rng);
            Ok((a, b))
        }
        Payload::Handle { .. } => {
            Err("handle payloads resolve through the operand store".into())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn materialize_synthetic() {
        let (a, b) = materialize(
            32,
            Payload::Synthetic { sparsity: 0.9, pattern: "uniform".into(), seed: 1 },
        )
        .unwrap();
        assert_eq!((a.rows, b.rows), (32, 32));
        assert!(a.sparsity() > 0.8);
    }

    #[test]
    fn materialize_unknown_pattern_errors() {
        let r = materialize(8, Payload::Synthetic { sparsity: 0.5, pattern: "x".into(), seed: 0 });
        assert!(r.is_err());
    }

    #[test]
    fn materialize_inline() {
        let (a, _b) = materialize(
            2,
            Payload::Inline { a: vec![1.0, 0.0, 0.0, 1.0], b: vec![5.0; 4] },
        )
        .unwrap();
        assert_eq!(a[(1, 1)], 1.0);
    }

    #[test]
    fn ephemeral_binding_assigns_distinct_free_ports() {
        use crate::coordinator::{Coordinator, CoordinatorConfig};
        use crate::runtime::Registry;
        let reg = Arc::new(
            Registry::from_manifest_json(r#"{"artifacts": []}"#, "/nope".into()).unwrap(),
        );
        let coord = Arc::new(Coordinator::new(
            reg,
            CoordinatorConfig { workers: 1, ..Default::default() },
        ));
        let s1 = Server::bind(&ServerConfig::ephemeral(), Arc::clone(&coord)).unwrap();
        let s2 = Server::bind(&ServerConfig::ephemeral(), Arc::clone(&coord)).unwrap();
        let (a1, a2) = (s1.local_addr().unwrap(), s2.local_addr().unwrap());
        assert_ne!(a1.port(), 0, "OS must have assigned a real port");
        assert_ne!(a1.port(), a2.port(), "parallel binds must not collide");
    }
    // dispatch() against a live coordinator is covered by
    // rust/tests/serve_integration.rs.
}
