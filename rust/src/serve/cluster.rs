//! Sharded multi-coordinator cluster: N in-process coordinator nodes over
//! loopback TCP behind one thin, stateless router (DESIGN.md §Cluster).
//!
//! The router speaks both wire planes on the front (the same first-byte
//! sniff as `server.rs`) but never *re-encodes* a data-plane message: it
//! decodes a copy only to pick a node, then forwards the **raw bytes**
//! verbatim and relays the node's raw reply. Bit-faithfulness is therefore
//! structural — a K-node cluster answers every request with exactly the
//! bytes some single coordinator produced, which is what lets the
//! differential suite demand bitwise equality with a one-node deployment.
//!
//! Placement is pure ring arithmetic (`coordinator::shard`): each
//! `a_handle` routes to `ring.owner(handle)` — sound because a clustered
//! store only ever assigns ids its own ring position owns — and each
//! `put_a` routes by **content signature** (the same FNV-1a64 the store
//! dedups by), so re-registering identical content from any client lands
//! on the same node and dedups there. Inline/synthetic spdm payloads are
//! location-independent; they prefer their content owner (batch affinity)
//! but fail over to any live node.
//!
//! Hot-operand replication: the router counts handle traffic; once a
//! handle crosses `replicate_after` *and* the owner's store hit gauge
//! shows it serving from cache, the entry is re-registered on the next
//! `replicas − 1` ring successors (`Coordinator::replicate_entry` —
//! deterministic re-conversion, bitwise-identical slabs). Failover walks
//! the same successor list when the owner's server is down; when nobody
//! in the replica set serves, the client gets a **typed degradation
//! error** ([`DEGRADED_PREFIX`]) instead of a hang or a silent retry.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use super::protocol::{
    frame, parse_request, render_response, APayload, HandleInfo, Payload, Request, Response,
};
use super::server::{
    is_timeout, materialize_a, peek_byte, read_exact_interruptible, Server, ServerConfig,
};
use crate::coordinator::{
    ASig, Coordinator, CoordinatorConfig, MetricsSnapshot, OperandId, Ring, ShardSpec, TenantStat,
    DEFAULT_RING_SEED, DEFAULT_TENANT, DEFAULT_VNODES,
};
use crate::json::{self, Value};
use crate::runtime::Registry;

/// Every degradation error the router originates starts with this prefix,
/// so clients (and the differential suite) can distinguish "the cluster
/// could not serve this" from an ordinary per-request error a single node
/// would also have produced.
pub const DEGRADED_PREFIX: &str = "cluster degraded: ";

/// Membership codec version. A doc with any other version is a load-time
/// error — ring parameters silently drifting between router and nodes
/// would mean silent misrouting, the one failure mode the design forbids.
pub const MEMBERSHIP_VERSION: u64 = 1;

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Cluster size N (≥ 1). N = 1 is the degenerate cluster the
    /// differential suite compares against: same ring code path, dense
    /// id sequence, bitwise-identical replies.
    pub nodes: u32,
    /// Replica-set size R: owner + R−1 ring successors (capped at N).
    pub replicas: u32,
    /// Ring virtual nodes per physical node.
    pub vnodes: u32,
    /// Ring seed — carried in the membership doc; all parties must agree.
    pub seed: u64,
    /// Router-observed handle requests before an operand is considered
    /// hot and replicated to its ring successors.
    pub replicate_after: u64,
    /// Per-node coordinator configuration. The cluster fills in
    /// `shard` itself (one `ShardSpec` per node).
    pub node_cfg: CoordinatorConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 3,
            replicas: 2,
            vnodes: DEFAULT_VNODES,
            seed: DEFAULT_RING_SEED,
            replicate_after: 3,
            node_cfg: CoordinatorConfig::default(),
        }
    }
}

/// One node's row in the membership doc.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeInfo {
    pub id: u32,
    pub addr: String,
}

/// The versioned cluster membership document: everything a router (or a
/// cluster-aware client) needs to compute placement identically to every
/// other party — ring parameters plus the node address list. JSON on the
/// wire; the seed travels as a hex string because it exceeds the 2⁵³
/// integer range a JSON number (f64) can carry exactly.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Membership {
    pub version: u64,
    pub seed: u64,
    pub vnodes: u32,
    pub replicas: u32,
    pub nodes: Vec<NodeInfo>,
}

impl Membership {
    pub fn to_json(&self) -> String {
        let nodes = Value::Arr(
            self.nodes
                .iter()
                .map(|n| {
                    Value::obj()
                        .field("id", n.id as u64)
                        .field("addr", n.addr.as_str())
                        .build()
                })
                .collect(),
        );
        json::write(
            &Value::obj()
                .field("version", self.version)
                .field("seed_hex", format!("{:016x}", self.seed))
                .field("vnodes", self.vnodes as u64)
                .field("replicas", self.replicas as u64)
                .field("nodes", nodes)
                .build(),
        )
    }

    pub fn from_json(s: &str) -> Result<Membership, String> {
        let v = json::parse(s).map_err(|e| format!("membership: unparseable JSON ({e:?})"))?;
        let version = v
            .get("version")
            .and_then(Value::as_u64)
            .ok_or_else(|| "membership: missing version".to_string())?;
        if version != MEMBERSHIP_VERSION {
            return Err(format!(
                "membership: version {version} is not the supported v{MEMBERSHIP_VERSION}"
            ));
        }
        let seed_hex = v
            .get("seed_hex")
            .and_then(Value::as_str)
            .ok_or_else(|| "membership: missing seed_hex".to_string())?;
        let seed = u64::from_str_radix(seed_hex, 16)
            .map_err(|_| format!("membership: bad seed_hex {seed_hex:?}"))?;
        let vnodes = v
            .get("vnodes")
            .and_then(Value::as_u64)
            .ok_or_else(|| "membership: missing vnodes".to_string())? as u32;
        if vnodes == 0 {
            return Err("membership: vnodes must be >= 1".into());
        }
        let replicas = v
            .get("replicas")
            .and_then(Value::as_u64)
            .ok_or_else(|| "membership: missing replicas".to_string())? as u32;
        let rows = v
            .get("nodes")
            .and_then(Value::as_arr)
            .ok_or_else(|| "membership: missing nodes".to_string())?;
        let mut nodes = Vec::with_capacity(rows.len());
        for (i, row) in rows.iter().enumerate() {
            let id = row
                .get("id")
                .and_then(Value::as_u64)
                .ok_or_else(|| "membership: node row missing id".to_string())?
                as u32;
            // Ids must be dense 0..N: ring positions are derived from the
            // index, so a sparse id space would diverge from placement.
            if id as usize != i {
                return Err(format!("membership: node ids must be dense 0..N (got {id} at row {i})"));
            }
            let addr = row
                .get("addr")
                .and_then(Value::as_str)
                .ok_or_else(|| "membership: node row missing addr".to_string())?
                .to_string();
            nodes.push(NodeInfo { id, addr });
        }
        if nodes.is_empty() {
            return Err("membership: empty node list".into());
        }
        Ok(Membership { version, seed, vnodes, replicas, nodes })
    }

    /// The ring this membership describes — identical on every party that
    /// holds the same doc.
    pub fn ring(&self) -> Ring {
        Ring::new(self.nodes.len() as u32, self.vnodes, self.seed)
    }
}

/// A running cluster: N coordinator nodes (each a full `Server` on an
/// ephemeral loopback port, its store shard-filtered to its ring slice)
/// plus the router front end. Dropping (or `shutdown`) stops everything.
pub struct Cluster {
    nodes: Vec<NodeHandle>,
    shared: Arc<RouterShared>,
    membership: Membership,
    router_addr: String,
    router_stop: Arc<AtomicBool>,
    router_thread: Option<std::thread::JoinHandle<()>>,
}

struct NodeHandle {
    coord: Arc<Coordinator>,
    addr: String,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// State every router connection shares: the ring, the node table (wire
/// address for the data plane, in-process `Arc<Coordinator>` for the
/// control plane — aggregation and replication never cross the wire),
/// and the hot-handle counters.
struct RouterShared {
    ring: Ring,
    seed: u64,
    vnodes: u32,
    replicas: u32,
    replicate_after: u64,
    nodes: Vec<NodeRef>,
    /// Router-observed handle-spdm counts, the replication trigger.
    hot: Mutex<HashMap<u64, u64>>,
}

struct NodeRef {
    addr: String,
    coord: Arc<Coordinator>,
}

impl Cluster {
    pub fn start(cfg: &ClusterConfig, registry: Arc<Registry>) -> std::io::Result<Cluster> {
        assert!(cfg.nodes >= 1, "a cluster needs at least one node");
        let mut nodes = Vec::with_capacity(cfg.nodes as usize);
        for i in 0..cfg.nodes {
            let mut node_cfg = cfg.node_cfg.clone();
            // Spill slab files are named by handle id, so nodes sharing one
            // directory would clobber each other — each node spills into
            // its own subdirectory.
            if let Some(dir) = &cfg.node_cfg.spill_dir {
                node_cfg.spill_dir = Some(dir.join(format!("node{i}")));
            }
            node_cfg.shard =
                Some(ShardSpec { nodes: cfg.nodes, node: i, vnodes: cfg.vnodes, seed: cfg.seed });
            let coord = Arc::new(Coordinator::new(Arc::clone(&registry), node_cfg));
            let server = Server::bind(&ServerConfig::ephemeral(), Arc::clone(&coord))?;
            let addr = server.local_addr()?.to_string();
            let stop = server.stop_handle();
            let thread = std::thread::spawn(move || {
                let _ = server.run();
            });
            nodes.push(NodeHandle { coord, addr, stop, thread: Some(thread) });
        }
        let shared = Arc::new(RouterShared {
            ring: Ring::new(cfg.nodes, cfg.vnodes, cfg.seed),
            seed: cfg.seed,
            vnodes: cfg.vnodes,
            replicas: cfg.replicas.max(1),
            replicate_after: cfg.replicate_after.max(1),
            nodes: nodes
                .iter()
                .map(|n| NodeRef { addr: n.addr.clone(), coord: Arc::clone(&n.coord) })
                .collect(),
            hot: Mutex::new(HashMap::new()),
        });
        let membership = Membership {
            version: MEMBERSHIP_VERSION,
            seed: cfg.seed,
            vnodes: cfg.vnodes,
            replicas: shared.replicas,
            nodes: nodes
                .iter()
                .enumerate()
                .map(|(i, n)| NodeInfo { id: i as u32, addr: n.addr.clone() })
                .collect(),
        };
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let router_addr = listener.local_addr()?.to_string();
        let router_stop = Arc::new(AtomicBool::new(false));
        let router_thread = {
            let stop = Arc::clone(&router_stop);
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || {
                let _ = router_accept_loop(listener, &shared, &stop);
            })
        };
        Ok(Cluster {
            nodes,
            shared,
            membership,
            router_addr,
            router_stop,
            router_thread: Some(router_thread),
        })
    }

    /// The router's front-end address — what clients dial.
    pub fn router_addr(&self) -> &str {
        &self.router_addr
    }

    pub fn membership(&self) -> &Membership {
        &self.membership
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Node `i`'s in-process coordinator — the control-plane view tests
    /// use to read per-node gauges and stores directly.
    pub fn coordinator(&self, i: usize) -> Arc<Coordinator> {
        Arc::clone(&self.nodes[i].coord)
    }

    pub fn node_addr(&self, i: usize) -> &str {
        &self.nodes[i].addr
    }

    /// The node owning `key` (handle id or content signature).
    pub fn owner_of(&self, key: u64) -> u32 {
        self.shared.ring.owner(key)
    }

    /// The failover order for `key`: owner first, then ring successors.
    pub fn replica_chain(&self, key: u64) -> Vec<u32> {
        self.shared.ring.replicas(key, self.shared.replicas)
    }

    /// Force-replicate a handle to its ring successors now (the same
    /// operation hot-operand traffic triggers). Returns how many fresh
    /// replicas were installed (already-resident ones are skipped).
    pub fn replicate(&self, a_handle: u64) -> Result<usize, String> {
        let chain = self.shared.ring.replicas(a_handle, self.shared.replicas);
        let owner = &self.shared.nodes[chain[0] as usize];
        let entry = owner
            .coord
            .store()
            .peek_entry(OperandId(a_handle))
            .ok_or_else(|| format!("a#{a_handle} is not registered on its owner node {}", chain[0]))?;
        let mut installed = 0;
        for &rep in &chain[1..] {
            let coord = &self.shared.nodes[rep as usize].coord;
            if coord.store().peek_entry(OperandId(a_handle)).is_none() {
                coord.replicate_entry(&entry)?;
                installed += 1;
            }
        }
        Ok(installed)
    }

    /// Stop node `i`'s TCP server (the coordinator stays alive, holding
    /// its store — this models a node whose serving endpoint is down,
    /// the failover case the differential suite drives).
    pub fn stop_node(&mut self, i: usize) {
        self.nodes[i].stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.nodes[i].thread.take() {
            let _ = t.join();
        }
    }

    /// Cluster-wide aggregated metrics: counters, gauges, histograms and
    /// per-algo tallies sum across nodes (see [`aggregate_snapshots`]).
    pub fn snapshot(&self) -> MetricsSnapshot {
        aggregate(&self.shared)
    }

    pub fn shutdown(&mut self) {
        self.router_stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.router_thread.take() {
            let _ = t.join();
        }
        for n in &mut self.nodes {
            n.stop.store(true, Ordering::SeqCst);
            if let Some(t) = n.thread.take() {
                let _ = t.join();
            }
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn router_accept_loop(
    listener: TcpListener,
    shared: &Arc<RouterShared>,
    stop: &Arc<AtomicBool>,
) -> std::io::Result<()> {
    listener.set_nonblocking(true)?;
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                let shared = Arc::clone(shared);
                let stop = Arc::clone(stop);
                conns.push(std::thread::spawn(move || {
                    let _ = router_connection(stream, &shared, &stop);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

/// One front-end connection: the same sniff-and-dispatch loop as
/// `server::handle_connection`, except each message is *routed* (raw-byte
/// forwarded) instead of dispatched locally. Backend connections are
/// per-front-connection and lazy, so one slow client never holds locks
/// other clients contend on.
fn router_connection(
    stream: TcpStream,
    shared: &RouterShared,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let mut payload: Vec<u8> = Vec::new();
    let mut backends = Backends::new(shared.nodes.len());
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let first = if line.is_empty() {
            match peek_byte(&mut reader, stop)? {
                Some(b) => b,
                None => return Ok(()),
            }
        } else {
            b'{'
        };
        if first == frame::MAGIC {
            let mut hdr = [0u8; frame::HEADER_LEN];
            if !read_exact_interruptible(&mut reader, &mut hdr, stop)? {
                return Ok(());
            }
            let h = match frame::parse_header(&hdr) {
                Ok(h) => h,
                Err(e) => {
                    writer.write_all(&frame::encode_resp_err(0, &e))?;
                    writer.flush()?;
                    return Ok(());
                }
            };
            payload.resize(h.len, 0);
            if !read_exact_interruptible(&mut reader, &mut payload, stop)? {
                return Ok(());
            }
            let reply = route_frame(&hdr, h.ftype, &payload, shared, &mut backends);
            writer.write_all(&reply)?;
            writer.flush()?;
        } else {
            match reader.read_line(&mut line) {
                Ok(0) => return Ok(()),
                Ok(_) => {
                    let request = line.trim().to_string();
                    line.clear();
                    if request.is_empty() {
                        continue;
                    }
                    let reply = route_json(&request, shared, &mut backends, stop);
                    writer.write_all(reply.as_bytes())?;
                    writer.write_all(b"\n")?;
                    writer.flush()?;
                }
                Err(e) if is_timeout(&e) => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

/// Lazily-dialed backend connections, one slot per node, owned by a
/// single front-end connection. Any transport error drops the slot so
/// the next use re-dials — which is also how a stopped node is detected
/// (connect refused, or EOF on a connection its server closed).
struct Backends {
    conns: Vec<Option<Conn>>,
}

struct Conn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Backends {
    fn new(n: usize) -> Backends {
        Backends { conns: (0..n).map(|_| None).collect() }
    }

    fn conn(&mut self, shared: &RouterShared, node: u32) -> std::io::Result<&mut Conn> {
        let slot = &mut self.conns[node as usize];
        if slot.is_none() {
            let stream = TcpStream::connect(&shared.nodes[node as usize].addr)?;
            let reader = BufReader::new(stream.try_clone()?);
            *slot = Some(Conn { writer: stream, reader });
        }
        Ok(slot.as_mut().unwrap())
    }

    /// Forward one JSON line, return the node's reply line (newline
    /// stripped) — relayed verbatim to the client.
    fn json(&mut self, shared: &RouterShared, node: u32, line: &str) -> std::io::Result<String> {
        let r = (|| {
            let c = self.conn(shared, node)?;
            c.writer.write_all(line.as_bytes())?;
            c.writer.write_all(b"\n")?;
            c.writer.flush()?;
            let mut buf = String::new();
            if c.reader.read_line(&mut buf)? == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "backend closed the connection",
                ));
            }
            buf.truncate(buf.trim_end().len());
            Ok(buf)
        })();
        if r.is_err() {
            self.conns[node as usize] = None;
        }
        r
    }

    /// Forward one raw v3 frame, return the node's raw reply frame
    /// (header + payload) — relayed verbatim to the client.
    fn frame(&mut self, shared: &RouterShared, node: u32, raw: &[u8]) -> std::io::Result<Vec<u8>> {
        let r = (|| {
            let c = self.conn(shared, node)?;
            c.writer.write_all(raw)?;
            c.writer.flush()?;
            let mut hdr = [0u8; frame::HEADER_LEN];
            c.reader.read_exact(&mut hdr)?;
            let h = frame::parse_header(&hdr)
                .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
            let mut reply = Vec::with_capacity(frame::HEADER_LEN + h.len);
            reply.extend_from_slice(&hdr);
            let start = reply.len();
            reply.resize(start + h.len, 0);
            c.reader.read_exact(&mut reply[start..])?;
            Ok(reply)
        })();
        if r.is_err() {
            self.conns[node as usize] = None;
        }
        r
    }
}

fn degraded_msg(why: &str) -> String {
    format!("{DEGRADED_PREFIX}{why}")
}

fn degraded_response(id: u64, why: &str) -> Response {
    Response { id, ok: false, error: Some(degraded_msg(why)), ..Default::default() }
}

/// Route one JSON request line. Data-plane requests forward raw; control
///-plane requests (metrics/stats/explain/list_a) aggregate across the
/// in-process coordinators — they describe the *cluster*, so their shape
/// intentionally sums rather than proxies one node's view.
fn route_json(line: &str, shared: &RouterShared, be: &mut Backends, stop: &AtomicBool) -> String {
    let req = match parse_request(line) {
        Ok(r) => r,
        Err(e) => {
            let id = json::parse(line)
                .ok()
                .and_then(|v| v.get("id").and_then(Value::as_u64))
                .unwrap_or(0);
            return render_response(&Response { id, ok: false, error: Some(e), ..Default::default() });
        }
    };
    match req {
        // The router answers liveness itself — the rendered bytes are
        // identical to a single server's reply, and a ping must succeed
        // even with every node down (it probes the front end).
        Request::Ping { id } => render_response(&Response { id, ok: true, ..Default::default() }),
        Request::Shutdown { id } => {
            // Broadcast, then stop the router. Nodes already down are
            // already stopped — their error is not the client's problem.
            for node in 0..shared.nodes.len() as u32 {
                let _ = be.json(shared, node, line);
            }
            stop.store(true, Ordering::SeqCst);
            render_response(&Response { id, ok: true, ..Default::default() })
        }
        Request::Metrics { id } => render_response(&Response {
            id,
            ok: true,
            metrics: Some(aggregate(shared).render()),
            ..Default::default()
        }),
        Request::Stats { id } => render_response(&Response {
            id,
            ok: true,
            metrics: Some(aggregate(shared).to_json()),
            ..Default::default()
        }),
        Request::Explain { id } => render_response(&Response {
            id,
            ok: true,
            routing: Some(cluster_explain_json(shared)),
            ..Default::default()
        }),
        Request::ListA { id } => {
            let mut handles: Vec<HandleInfo> = shared
                .nodes
                .iter()
                .flat_map(|n| n.coord.list_a())
                .map(|s| HandleInfo {
                    a_handle: s.handle.0,
                    n: s.n,
                    nnz: s.nnz,
                    algo: s.algo.as_str().to_string(),
                    artifact: s.artifact,
                    bytes: s.bytes,
                    tier: s.tier.to_string(),
                    last_used_seq: s.last_used_seq,
                })
                .collect();
            // Replica copies are the same logical operand — one row each,
            // and a RAM-resident copy wins over a spilled one (the row
            // should describe the best tier the cluster can serve from).
            handles.sort_by_key(|h| (h.a_handle, h.tier != "ram"));
            handles.dedup_by_key(|h| h.a_handle);
            render_response(&Response { id, ok: true, handles: Some(handles), ..Default::default() })
        }
        Request::DropA { id, a_handle } => {
            // Mutations require the owner (a replica-side drop would
            // resurrect on the next failover read). Owner reply relays
            // verbatim; replica copies and the hot counter retire
            // in-process afterwards.
            let chain = shared.ring.replicas(a_handle, shared.replicas);
            match be.json(shared, chain[0], line) {
                Ok(reply) => {
                    for &rep in &chain[1..] {
                        shared.nodes[rep as usize].coord.drop_a(OperandId(a_handle));
                    }
                    shared.hot.lock().unwrap().remove(&a_handle);
                    reply
                }
                Err(_) => render_response(&degraded_response(
                    id,
                    &format!("drop_a owner node {} of a#{a_handle} is unreachable", chain[0]),
                )),
            }
        }
        Request::PutA { id, n, payload, tenant, .. } => {
            let key = match put_key(n, payload, &tenant) {
                Ok(k) => k,
                Err(e) => {
                    return render_response(&Response {
                        id,
                        ok: false,
                        error: Some(e),
                        ..Default::default()
                    })
                }
            };
            let owner = shared.ring.owner(key);
            match be.json(shared, owner, line) {
                Ok(reply) => reply,
                Err(_) => render_response(&degraded_response(
                    id,
                    &format!("put_a owner node {owner} is unreachable"),
                )),
            }
        }
        Request::Spdm { id, n, payload, tenant, .. } => match payload {
            Payload::Handle { a_handle, .. } => {
                note_handle_traffic(shared, a_handle);
                let chain = shared.ring.replicas(a_handle, shared.replicas);
                for (i, &node) in chain.iter().enumerate() {
                    if let Ok(reply) = be.json(shared, node, line) {
                        // The owner's answer is authoritative, including
                        // "unknown handle". A *replica* saying unknown
                        // only means the copy isn't there — keep walking.
                        if i > 0 && reply.contains("unknown operand handle") {
                            continue;
                        }
                        return reply;
                    }
                }
                render_response(&degraded_response(
                    id,
                    &format!(
                        "owner node {} of a#{a_handle} is unreachable and no replica serves it",
                        chain[0]
                    ),
                ))
            }
            Payload::Inline { ref a, .. } => {
                forward_json_any(line, id, mix_tenant(content_key(n, a), &tenant), shared, be)
            }
            Payload::Synthetic { sparsity, ref pattern, seed } => forward_json_any(
                line,
                id,
                mix_tenant(synthetic_key(n, sparsity, pattern, seed), &tenant),
                shared,
                be,
            ),
        },
    }
}

/// Location-independent payloads (inline/synthetic spdm): prefer the
/// content owner so identical content batches on one node, but any live
/// node computes the identical answer — fail over through the whole ring.
fn forward_json_any(
    line: &str,
    id: u64,
    key: u64,
    shared: &RouterShared,
    be: &mut Backends,
) -> String {
    for &node in &shared.ring.replicas(key, shared.ring.nodes()) {
        if let Ok(reply) = be.json(shared, node, line) {
            return reply;
        }
    }
    render_response(&degraded_response(id, "no cluster node is reachable"))
}

/// Route one binary v3 frame. Same decision tree as the JSON plane; the
/// forwarded bytes are the client's original header + payload, and the
/// reply is the node's raw frame.
fn route_frame(
    hdr: &[u8; frame::HEADER_LEN],
    ftype: u8,
    payload: &[u8],
    shared: &RouterShared,
    be: &mut Backends,
) -> Vec<u8> {
    let (req, _want_c) = match frame::decode_request(ftype, payload) {
        Ok(x) => x,
        Err(e) => return frame::encode_resp_err(frame::request_id_hint(payload), &e),
    };
    let mut raw = Vec::with_capacity(frame::HEADER_LEN + payload.len());
    raw.extend_from_slice(hdr);
    raw.extend_from_slice(payload);
    match req {
        Request::Ping { id } => frame::encode_resp_pong(id),
        Request::PutA { id, n, payload, tenant, .. } => {
            let key = match put_key(n, payload, &tenant) {
                Ok(k) => k,
                Err(e) => return frame::encode_resp_err(id, &e),
            };
            let owner = shared.ring.owner(key);
            match be.frame(shared, owner, &raw) {
                Ok(reply) => reply,
                Err(_) => frame::encode_resp_err(
                    id,
                    &degraded_msg(&format!("put_a owner node {owner} is unreachable")),
                ),
            }
        }
        Request::Spdm { id, n, payload, tenant, .. } => match payload {
            Payload::Handle { a_handle, .. } => {
                note_handle_traffic(shared, a_handle);
                let chain = shared.ring.replicas(a_handle, shared.replicas);
                for (i, &node) in chain.iter().enumerate() {
                    if let Ok(reply) = be.frame(shared, node, &raw) {
                        if i > 0 && frame_is_unknown_handle(&reply) {
                            continue;
                        }
                        return reply;
                    }
                }
                frame::encode_resp_err(
                    id,
                    &degraded_msg(&format!(
                        "owner node {} of a#{a_handle} is unreachable and no replica serves it",
                        chain[0]
                    )),
                )
            }
            Payload::Inline { ref a, .. } => {
                forward_frame_any(&raw, id, mix_tenant(content_key(n, a), &tenant), shared, be)
            }
            Payload::Synthetic { sparsity, ref pattern, seed } => forward_frame_any(
                &raw,
                id,
                mix_tenant(synthetic_key(n, sparsity, pattern, seed), &tenant),
                shared,
                be,
            ),
        },
        // decode_request only yields Spdm/PutA/Ping from v3 frame types;
        // answer defensively rather than panic at a trust boundary.
        _ => frame::encode_resp_err(0, "unsupported frame request"),
    }
}

fn forward_frame_any(
    raw: &[u8],
    id: u64,
    key: u64,
    shared: &RouterShared,
    be: &mut Backends,
) -> Vec<u8> {
    for &node in &shared.ring.replicas(key, shared.ring.nodes()) {
        if let Ok(reply) = be.frame(shared, node, raw) {
            return reply;
        }
    }
    frame::encode_resp_err(id, &degraded_msg("no cluster node is reachable"))
}

/// Is this raw reply frame a typed error naming an unknown handle?
/// (Error payload layout: `id u64 | utf8 message`.)
fn frame_is_unknown_handle(reply: &[u8]) -> bool {
    if reply.len() < frame::HEADER_LEN + 8 {
        return false;
    }
    let hdr: [u8; frame::HEADER_LEN] = match reply[..frame::HEADER_LEN].try_into() {
        Ok(h) => h,
        Err(_) => return false,
    };
    match frame::parse_header(&hdr) {
        Ok(h) if h.ftype == frame::FT_RESP_ERR => {
            std::str::from_utf8(&reply[frame::HEADER_LEN + 8..])
                .map(|m| m.contains("unknown operand handle"))
                .unwrap_or(false)
        }
        _ => false,
    }
}

/// Count one routed handle request; once the handle crosses the hot
/// threshold *and* the owner's store hit gauge confirms it is serving
/// from cache (the gauge `peek_dims` now feeds symmetrically), install
/// replicas on the ring successors. Synchronous and idempotent —
/// already-resident replicas are skipped, so steady-state cost is one
/// map lookup per node. Runs through the in-process coordinators, so a
/// node whose *server* is down can still receive (or donate) a replica.
fn note_handle_traffic(shared: &RouterShared, a_handle: u64) {
    let count = {
        let mut hot = shared.hot.lock().unwrap();
        let c = hot.entry(a_handle).or_insert(0);
        *c += 1;
        *c
    };
    if count < shared.replicate_after {
        return;
    }
    let chain = shared.ring.replicas(a_handle, shared.replicas);
    if chain.len() < 2 {
        return;
    }
    let owner = &shared.nodes[chain[0] as usize];
    if owner.coord.store().stats().hits == 0 {
        return;
    }
    let entry = match owner.coord.store().peek_entry(OperandId(a_handle)) {
        Some(e) => e,
        None => return,
    };
    for &rep in &chain[1..] {
        let coord = &shared.nodes[rep as usize].coord;
        if coord.store().peek_entry(OperandId(a_handle)).is_none() {
            let _ = coord.replicate_entry(&entry);
        }
    }
}

/// Routing key for `put_a`: the FNV-1a64 content signature — the same
/// hash the store dedups by, so identical content always lands (and
/// dedups) on one node. Synthetic payloads are materialized first so an
/// inline re-registration of the generated matrix routes identically.
/// The owning tenant folds into the key ([`mix_tenant`]) because store
/// dedup is per-tenant: two tenants registering the same bytes are
/// distinct operands and may as well land on distinct nodes.
fn put_key(n: usize, payload: APayload, tenant: &str) -> Result<u64, String> {
    let key = match payload {
        APayload::Inline { ref a } => content_key(n, a),
        payload @ APayload::Synthetic { .. } => {
            let m = materialize_a(n, payload)?;
            ASig::of(&m).hash
        }
    };
    Ok(mix_tenant(key, tenant))
}

/// Fold a tenant id into a routing key. The `default` tenant returns the
/// key untouched — untenanted traffic must place exactly as it did before
/// tenancy existed (the N-node differential suite pins this).
fn mix_tenant(key: u64, tenant: &str) -> u64 {
    if tenant == DEFAULT_TENANT {
        return key;
    }
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = key;
    for b in tenant.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// FNV-1a64 over `(rows, cols, element bits)` — bit-for-bit the scheme of
/// `ASig::of`, applied to a raw payload slice without building a `Mat`.
fn content_key(n: usize, data: &[f32]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut mix = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(PRIME);
    };
    mix(n as u64);
    mix(n as u64);
    for &v in data {
        mix(v.to_bits() as u64);
    }
    h
}

/// Routing key for synthetic spdm payloads: a deterministic hash of the
/// generation parameters (cheaper than materializing n² floats just to
/// route a location-independent request).
fn synthetic_key(n: usize, sparsity: f64, pattern: &str, seed: u64) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut mix = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(PRIME);
    };
    mix(n as u64);
    mix(sparsity.to_bits());
    for b in pattern.bytes() {
        mix(b as u64);
    }
    mix(seed);
    h
}

fn aggregate(shared: &RouterShared) -> MetricsSnapshot {
    let snaps: Vec<MetricsSnapshot> =
        shared.nodes.iter().map(|n| n.coord.snapshot()).collect();
    aggregate_snapshots(&snaps)
}

/// Merge per-node snapshots into one cluster view: every counter, gauge,
/// histogram bucket and per-algo tally **sums exactly** (the property the
/// differential suite pins); throughput sums; latency percentiles take
/// the max across nodes (a conservative cluster tail — percentiles of
/// disjoint populations don't add); phase means weight by completed jobs.
pub fn aggregate_snapshots(snaps: &[MetricsSnapshot]) -> MetricsSnapshot {
    let mut out = MetricsSnapshot {
        submitted: 0,
        completed: 0,
        errors: 0,
        verify_failures: 0,
        bytes_copied: 0,
        copies_avoided: 0,
        conversions_amortized: 0,
        conversions_total: 0,
        store_entries: 0,
        store_bytes: 0,
        store_budget_bytes: 0,
        store_hits: 0,
        store_misses: 0,
        store_evictions: 0,
        spill_writes: 0,
        spill_promotes: 0,
        spill_bytes: 0,
        route_flips: 0,
        explorations: 0,
        window_hits: 0,
        window_timeouts: 0,
        batch_hist: Vec::new(),
        throughput_rps: 0.0,
        p50_s: 0.0,
        p95_s: 0.0,
        p99_s: 0.0,
        mean_kernel_s: 0.0,
        mean_convert_s: 0.0,
        per_algo: HashMap::new(),
        tenants: Vec::new(),
    };
    let (mut kernel_w, mut convert_w, mut weight) = (0.0f64, 0.0f64, 0u64);
    for s in snaps {
        out.submitted += s.submitted;
        out.completed += s.completed;
        out.errors += s.errors;
        out.verify_failures += s.verify_failures;
        out.bytes_copied += s.bytes_copied;
        out.copies_avoided += s.copies_avoided;
        out.conversions_amortized += s.conversions_amortized;
        out.conversions_total += s.conversions_total;
        out.store_entries += s.store_entries;
        out.store_bytes += s.store_bytes;
        out.store_budget_bytes += s.store_budget_bytes;
        out.store_hits += s.store_hits;
        out.store_misses += s.store_misses;
        out.store_evictions += s.store_evictions;
        out.spill_writes += s.spill_writes;
        out.spill_promotes += s.spill_promotes;
        out.spill_bytes += s.spill_bytes;
        out.route_flips += s.route_flips;
        out.explorations += s.explorations;
        out.window_hits += s.window_hits;
        out.window_timeouts += s.window_timeouts;
        if s.batch_hist.len() > out.batch_hist.len() {
            out.batch_hist.resize(s.batch_hist.len(), 0);
        }
        for (w, &c) in s.batch_hist.iter().enumerate() {
            out.batch_hist[w] += c;
        }
        out.throughput_rps += s.throughput_rps;
        out.p50_s = out.p50_s.max(s.p50_s);
        out.p95_s = out.p95_s.max(s.p95_s);
        out.p99_s = out.p99_s.max(s.p99_s);
        kernel_w += s.mean_kernel_s * s.completed as f64;
        convert_w += s.mean_convert_s * s.completed as f64;
        weight += s.completed;
        for (k, v) in &s.per_algo {
            *out.per_algo.entry(*k).or_insert(0) += v;
        }
        // Tenant rows merge by name: bytes, slice budgets, rejection
        // counters and lane gauges all sum (each node holds its own shard
        // of a tenant's operands and its own DRR lane for the tenant).
        for t in &s.tenants {
            match out.tenants.iter_mut().find(|o| o.name == t.name) {
                Some(o) => {
                    o.bytes += t.bytes;
                    o.slice_budget_bytes += t.slice_budget_bytes;
                    o.rate_limited += t.rate_limited;
                    o.quota_exceeded += t.quota_exceeded;
                    o.lane_depth += t.lane_depth;
                    o.lane_deficit += t.lane_deficit;
                }
                None => out.tenants.push(t.clone()),
            }
        }
    }
    out.tenants.sort_by(|a, b| a.name.cmp(&b.name));
    if weight > 0 {
        out.mean_kernel_s = kernel_w / weight as f64;
        out.mean_convert_s = convert_w / weight as f64;
    }
    out
}

/// Cluster `explain`: the ring parameters plus every node's own explain
/// document embedded verbatim (parsed and re-nested, not re-derived).
fn cluster_explain_json(shared: &RouterShared) -> String {
    let nodes: Vec<Value> = shared
        .nodes
        .iter()
        .enumerate()
        .map(|(i, n)| {
            let doc = json::parse(&n.coord.explain_json()).unwrap_or(Value::Null);
            Value::obj()
                .field("node", i)
                .field("addr", n.addr.as_str())
                .field("routing", doc)
                .build()
        })
        .collect();
    json::write(
        &Value::obj()
            .field(
                "cluster",
                Value::obj()
                    .field("nodes", shared.nodes.len())
                    .field("replicas", shared.replicas as u64)
                    .field("vnodes", shared.vnodes as u64)
                    .field("seed_hex", format!("{:016x}", shared.seed))
                    .build(),
            )
            .field("nodes", Value::Arr(nodes))
            .build(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndarray::Mat;

    #[test]
    fn membership_codec_round_trips_exactly() {
        let m = Membership {
            version: MEMBERSHIP_VERSION,
            seed: DEFAULT_RING_SEED, // > 2^53: must survive JSON exactly
            vnodes: DEFAULT_VNODES,
            replicas: 2,
            nodes: vec![
                NodeInfo { id: 0, addr: "127.0.0.1:4100".into() },
                NodeInfo { id: 1, addr: "127.0.0.1:4101".into() },
                NodeInfo { id: 2, addr: "127.0.0.1:4102".into() },
            ],
        };
        let back = Membership::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.seed, 0x5EED_C0DE_0B57_AC1E, "seed survives the hex codec bit-exactly");
        // Both parties derive the identical ring from the doc.
        let (r1, r2) = (m.ring(), back.ring());
        for key in 0..1_000u64 {
            assert_eq!(r1.owner(key), r2.owner(key));
        }
    }

    #[test]
    fn membership_codec_rejects_version_skew_and_malformed_docs() {
        let good = Membership {
            version: MEMBERSHIP_VERSION,
            seed: 7,
            vnodes: 4,
            replicas: 2,
            nodes: vec![NodeInfo { id: 0, addr: "127.0.0.1:1".into() }],
        }
        .to_json();
        let skewed = good.replace("\"version\":1", "\"version\":2");
        let err = Membership::from_json(&skewed).unwrap_err();
        assert!(err.contains("version 2"), "version mismatch must be a load-time error: {err}");
        assert!(Membership::from_json("{}").is_err());
        assert!(Membership::from_json("not json").is_err());
        // Sparse node ids would desynchronize placement.
        let sparse = good.replace("\"id\":0", "\"id\":5");
        assert!(Membership::from_json(&sparse).unwrap_err().contains("dense"));
    }

    #[test]
    fn content_key_matches_the_store_signature() {
        let data = vec![1.0f32, 0.0, -2.5, 3.25, 0.0, 7.0, 0.0, 0.0, 1.5];
        let m = Mat::from_vec(3, 3, data.clone());
        assert_eq!(
            content_key(3, &data),
            ASig::of(&m).hash,
            "router routes put_a by the exact signature the store dedups by"
        );
    }

    #[test]
    fn aggregate_snapshots_sums_counters_histograms_and_per_algo() {
        let mut a = aggregate_snapshots(&[]);
        a.submitted = 3;
        a.completed = 2;
        a.store_hits = 5;
        a.spill_writes = 2;
        a.spill_promotes = 1;
        a.spill_bytes = 100;
        a.batch_hist = vec![0, 2, 1];
        a.mean_kernel_s = 2.0;
        a.per_algo.insert("gcoo", 2);
        let mut b = aggregate_snapshots(&[]);
        b.submitted = 4;
        b.completed = 4;
        b.store_hits = 7;
        b.spill_writes = 3;
        b.spill_promotes = 4;
        b.spill_bytes = 28;
        b.batch_hist = vec![0, 1, 0, 9];
        b.mean_kernel_s = 5.0;
        b.per_algo.insert("gcoo", 1);
        b.per_algo.insert("dense", 3);
        a.tenants = vec![
            TenantStat {
                name: "alpha".into(),
                bytes: 100,
                slice_budget_bytes: 1000,
                rate_limited: 2,
                quota_exceeded: 0,
                lane_depth: 1,
                lane_deficit: -3,
            },
            TenantStat { name: "beta".into(), bytes: 50, ..TenantStat::default() },
        ];
        b.tenants = vec![TenantStat {
            name: "alpha".into(),
            bytes: 30,
            slice_budget_bytes: 1000,
            rate_limited: 1,
            quota_exceeded: 4,
            lane_depth: 2,
            lane_deficit: 1,
        }];
        let sum = aggregate_snapshots(&[a, b]);
        assert_eq!(sum.submitted, 7);
        assert_eq!(sum.completed, 6);
        assert_eq!(sum.store_hits, 12);
        assert_eq!(
            (sum.spill_writes, sum.spill_promotes, sum.spill_bytes),
            (5, 5, 128),
            "spill gauges sum across nodes"
        );
        assert_eq!(sum.batch_hist, vec![0, 3, 1, 9], "ragged histograms sum bucket-wise");
        assert_eq!(sum.per_algo["gcoo"], 3);
        assert_eq!(sum.per_algo["dense"], 3);
        // completed-weighted phase mean: (2·2 + 5·4) / 6
        assert!((sum.mean_kernel_s - 4.0).abs() < 1e-12);
        // Tenant rows merge by name, every field summing across nodes.
        assert_eq!(
            sum.tenants,
            vec![
                TenantStat {
                    name: "alpha".into(),
                    bytes: 130,
                    slice_budget_bytes: 2000,
                    rate_limited: 3,
                    quota_exceeded: 4,
                    lane_depth: 3,
                    lane_deficit: -2,
                },
                TenantStat { name: "beta".into(), bytes: 50, ..TenantStat::default() },
            ],
            "per-tenant splits aggregate by name across the cluster"
        );
    }

    #[test]
    fn mix_tenant_leaves_default_placement_untouched() {
        let key = content_key(3, &[1.0f32, 0.0, 2.0, 0.0, 3.0, 0.0, 0.0, 0.0, 4.0]);
        assert_eq!(mix_tenant(key, DEFAULT_TENANT), key, "untenanted placement is pre-tenancy");
        let (alpha, beta) = (mix_tenant(key, "alpha"), mix_tenant(key, "beta"));
        assert_ne!(alpha, key, "tenanted keys diverge from the content key");
        assert_ne!(alpha, beta, "distinct tenants, distinct placement");
        assert_eq!(alpha, mix_tenant(key, "alpha"), "deterministic");
    }

    #[test]
    fn synthetic_key_separates_every_parameter() {
        let base = synthetic_key(64, 0.9, "uniform", 1);
        assert_ne!(base, synthetic_key(65, 0.9, "uniform", 1));
        assert_ne!(base, synthetic_key(64, 0.8, "uniform", 1));
        assert_ne!(base, synthetic_key(64, 0.9, "banded", 1));
        assert_ne!(base, synthetic_key(64, 0.9, "uniform", 2));
        assert_eq!(base, synthetic_key(64, 0.9, "uniform", 1), "deterministic");
    }
}
