//! Auto-tuning of the GCOO parameters p (band height) and b (block/tile
//! width) — the paper's stated future work ("we would like to consider the
//! auto-tune scheme to set proper p and b"), implemented here.
//!
//! Two stages:
//! 1. **Analytic pruning** — a closed-form cost model (same bottleneck lens
//!    as simgpu) ranks candidate (p, b) pairs from cheap structural
//!    statistics of the matrix (nnz, reuse-run histogram, band skew).
//! 2. **Measured refinement** — the top candidates are timed by the
//!    trace-derived cost oracle ([`simgpu::TraceOracle`]: traced kernel
//!    execution through the memory model; for the live system, the PJRT
//!    executables via the coordinator) and the empirical best wins.
//!    Results are cached per (n, sparsity-bucket, pattern-fingerprint).

use std::collections::HashMap;

use crate::simgpu::{DeviceConfig, GcooStructure, TraceOracle, WalkConfig};
use crate::sparse::Gcoo;

/// Candidate grids. p bounded by accumulator pressure (p·b·4B of registers/
/// VMEM per program); b by launch width.
pub const P_CANDIDATES: [usize; 4] = [4, 8, 16, 32];
pub const B_CANDIDATES: [usize; 3] = [64, 128, 256];

/// Cheap structural statistics driving the analytic model.
#[derive(Clone, Copy, Debug)]
pub struct MatrixStats {
    pub n: usize,
    pub nnz: usize,
    /// Fraction of entries that continue a same-column run within a band
    /// at the reference band height (p = 8).
    pub reuse_fraction: f64,
    /// max band nnz / mean band nnz (padding waste indicator).
    pub band_skew: f64,
}

impl MatrixStats {
    pub fn measure(gcoo: &Gcoo) -> MatrixStats {
        let nnz = gcoo.nnz().max(1);
        let reuse = gcoo.reuse_pairs() as f64 / nnz as f64;
        let mean = nnz as f64 / gcoo.num_groups() as f64;
        let skew = gcoo.max_group_nnz() as f64 / mean.max(1.0);
        MatrixStats { n: gcoo.n_cols, nnz, reuse_fraction: reuse, band_skew: skew }
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz as f64 / (self.n * self.n) as f64
    }
}

/// Analytic cost (arbitrary units — only the ranking matters).
///
/// Traffic ≈ staged-A reads (∝ nnz·n/b, cheap via shared) +
///           B gathers (∝ nnz·n·(1−reuse(p))/32, slow path) +
///           C writes (∝ n²·dup(p)) + launch (∝ blocks).
/// Larger p raises reuse within a band (more rows share columns) but also
/// accumulator pressure; larger b cuts A re-reads but wastes threads when
/// n % b ≠ 0 and lowers occupancy.
pub fn analytic_cost(stats: &MatrixStats, p: usize, b: usize) -> f64 {
    let n = stats.n as f64;
    let nnz = stats.nnz as f64;
    // reuse grows with band height: fraction of same-col pairs scales
    // roughly with (p/8) capped at 1 for uniform structure.
    let reuse_p = (stats.reuse_fraction * (p as f64 / 8.0)).min(0.95);
    let col_tiles = (n / b as f64).ceil();
    let a_traffic = nnz * col_tiles * 3.0; // staged loads (vals+rows+cols)
    let b_traffic = nnz * n * (1.0 - reuse_p) / 8.0; // gathers, sectorized
    let c_traffic = n * n / 8.0;
    // padding waste: skewed bands pay for max-band capacity.
    let pad_waste = (stats.band_skew - 1.0).max(0.0) * nnz * 0.1;
    // occupancy penalty: accumulator bytes per program = p*b*4; past 16KB
    // the model charges linearly (register/VMEM spill pressure).
    let acc_bytes = (p * b * 4) as f64;
    let occ_penalty = (acc_bytes / 16384.0 - 1.0).max(0.0) * b_traffic * 0.5;
    let launch = col_tiles * (n / p as f64).ceil() * 64.0;
    a_traffic + b_traffic + c_traffic + pad_waste + occ_penalty + launch
}

/// A tuning decision.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Choice {
    pub p: usize,
    pub b: usize,
    pub predicted_cost: f64,
    pub measured_s: Option<f64>,
}

/// Cache key: coarse bucket so near-identical workloads share decisions.
fn bucket(stats: &MatrixStats) -> (usize, i64, i64) {
    let log_n = (stats.n as f64).log2().round() as usize;
    let s_bucket = (stats.sparsity() * 200.0).round() as i64; // 0.5% buckets
    let r_bucket = (stats.reuse_fraction * 10.0).round() as i64;
    (log_n, s_bucket, r_bucket)
}

/// The tuner: analytic pruning + simulated refinement + memoization.
pub struct Autotuner {
    device: &'static DeviceConfig,
    cache: HashMap<(usize, i64, i64), Choice>,
    /// How many analytic leaders get measured.
    pub refine_top: usize,
}

impl Autotuner {
    pub fn new(device: &'static DeviceConfig) -> Self {
        Autotuner { device, cache: HashMap::new(), refine_top: 3 }
    }

    /// Rank all candidates analytically (best first).
    pub fn rank(&self, stats: &MatrixStats) -> Vec<Choice> {
        let mut out: Vec<Choice> = P_CANDIDATES
            .iter()
            .flat_map(|&p| {
                B_CANDIDATES.iter().map(move |&b| Choice {
                    p,
                    b,
                    predicted_cost: analytic_cost(stats, p, b),
                    measured_s: None,
                })
            })
            .collect();
        out.sort_by(|a, b| a.predicted_cost.partial_cmp(&b.predicted_cost).unwrap());
        out
    }

    /// Full tuning for a concrete matrix: prune analytically, measure the
    /// leaders in the simulator, memoize by bucket.
    pub fn tune(&mut self, gcoo: &Gcoo) -> Choice {
        let stats = MatrixStats::measure(gcoo);
        let key = bucket(&stats);
        if let Some(hit) = self.cache.get(&key) {
            return *hit;
        }
        let ranked = self.rank(&stats);
        let mut best: Option<Choice> = None;
        for cand in ranked.iter().take(self.refine_top) {
            // Re-band the matrix at candidate p and walk it.
            let rebanded;
            let structure = if cand.p == gcoo.p {
                GcooStructure::new(gcoo)
            } else {
                rebanded = reband(gcoo, cand.p);
                GcooStructure::new(&rebanded)
            };
            let cfg = WalkConfig { b: cand.b, sample_blocks: 32, seed: 7 };
            let oracle = TraceOracle::new(self.device, cfg);
            let t = oracle.gcoo_time(&structure, true);
            let mut c = *cand;
            c.measured_s = Some(t);
            if best.map_or(true, |b| t < b.measured_s.unwrap()) {
                best = Some(c);
            }
        }
        let decision = best.expect("refine_top >= 1");
        self.cache.insert(key, decision);
        decision
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }
}

/// Rebuild a GCOO at a different band height (via the dense-free CSR path).
fn reband(gcoo: &Gcoo, p: usize) -> Gcoo {
    // Gcoo -> Coo(absolute rows) -> Csr -> Gcoo(p)
    let mut triplets: Vec<(u32, u32, f32)> = Vec::with_capacity(gcoo.nnz());
    for gi in 0..gcoo.num_groups() {
        for (r, c, v) in gcoo.group(gi) {
            triplets.push(((gi * gcoo.p) as u32 + r, c, v));
        }
    }
    let coo = crate::sparse::Coo::from_triplets(gcoo.n_rows, gcoo.n_cols, &triplets)
        .expect("gcoo entries are unique");
    Gcoo::from_csr(&crate::sparse::Csr::from_coo(&coo), p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::ndarray::Mat;
    use crate::rng::Rng;
    use crate::simgpu::TITANX;
    use crate::sparse::ToDense;

    fn stats_for(pattern: gen::Pattern, n: usize, s: f64) -> (Gcoo, MatrixStats) {
        let mut rng = Rng::new(11);
        let a = gen::generate(pattern, n, s, &mut rng);
        let g = Gcoo::from_dense(&a, 8);
        let st = MatrixStats::measure(&g);
        (g, st)
    }

    #[test]
    fn stats_reuse_higher_for_dense_columns() {
        // At the paper's sparsity regime a diagonal matrix is a thin stripe:
        // entries in a band have distinct columns, so reuse ≈ 0, while a
        // dense-columns matrix is almost all same-column runs.
        let (_g1, s_diag) = stats_for(gen::Pattern::Diagonal, 128, 0.99);
        let (_g2, s_cols) = stats_for(gen::Pattern::DenseColumns, 128, 0.99);
        assert!(
            s_cols.reuse_fraction > s_diag.reuse_fraction + 0.3,
            "cols {} vs diag {}",
            s_cols.reuse_fraction,
            s_diag.reuse_fraction
        );
    }

    #[test]
    fn analytic_cost_prefers_reuse() {
        let (_g, mut st) = stats_for(gen::Pattern::Uniform, 256, 0.98);
        let lo = analytic_cost(&st, 8, 128);
        st.reuse_fraction = 0.9;
        let hi_reuse = analytic_cost(&st, 8, 128);
        assert!(hi_reuse < lo, "more reuse must predict lower cost");
    }

    #[test]
    fn rank_returns_all_candidates_sorted() {
        let (_g, st) = stats_for(gen::Pattern::Uniform, 256, 0.98);
        let tuner = Autotuner::new(&TITANX);
        let ranked = tuner.rank(&st);
        assert_eq!(ranked.len(), P_CANDIDATES.len() * B_CANDIDATES.len());
        for w in ranked.windows(2) {
            assert!(w[0].predicted_cost <= w[1].predicted_cost);
        }
    }

    #[test]
    fn tune_measures_and_caches() {
        let mut rng = Rng::new(12);
        let a = gen::uniform(128, 0.97, &mut rng);
        let g = Gcoo::from_dense(&a, 8);
        let mut tuner = Autotuner::new(&TITANX);
        let c1 = tuner.tune(&g);
        assert!(c1.measured_s.unwrap() > 0.0);
        assert_eq!(tuner.cache_len(), 1);
        let c2 = tuner.tune(&g);
        assert_eq!(c1, c2, "second call must hit the cache");
        assert_eq!(tuner.cache_len(), 1);
    }

    #[test]
    fn reband_preserves_matrix() {
        let mut rng = Rng::new(13);
        let a = gen::uniform(64, 0.9, &mut rng);
        let g8 = Gcoo::from_dense(&a, 8);
        let g16 = reband(&g8, 16);
        assert_eq!(g16.p, 16);
        assert_eq!(g16.to_dense(), a);
    }

    #[test]
    fn occupancy_penalty_caps_p_times_b() {
        let (_g, st) = stats_for(gen::Pattern::Uniform, 512, 0.99);
        // enormous accumulators must never win the ranking
        let huge = analytic_cost(&st, 32, 256);
        let sane = analytic_cost(&st, 8, 128);
        assert!(sane < huge);
    }
}
