//! Figure harness — regenerates every table and figure of the paper's
//! evaluation (§IV). Each `figN_*` function produces printable tables and
//! writes CSV series under `results/`; the `cargo bench` targets and the
//! `gcoospdm figures` CLI subcommand are thin wrappers over these.
//!
//! Scale knobs: the paper's corpus sizes (2694 public + 6968 random
//! matrices, n up to 36720) are CPU-hostile; every harness takes an explicit
//! scale so the default run finishes in minutes while `--full` approaches
//! the paper's counts. Sparsity ranges and all *relative* claims are kept
//! exactly.

use crate::bench::{Histogram, Series, Table};
use crate::convert;
use crate::gen::{self, CorpusSpec};
use crate::rng::Rng;
use crate::simgpu::{
    self, DeviceConfig, GcooStructure, SyntheticUniform, WalkConfig, ALL_DEVICES, TITANX,
};
use crate::sparse::{self, Gcoo};

/// Output bundle of one figure harness.
pub struct FigureOutput {
    pub tables: Vec<Table>,
    pub notes: Vec<String>,
}

impl FigureOutput {
    pub fn print(&self) {
        for t in &self.tables {
            println!("{}", t.render());
        }
        for n in &self.notes {
            println!("note: {n}");
        }
    }
}

fn series_table(title: &str, xname: &str, series: &[Series]) -> Table {
    let mut headers = vec![xname.to_string()];
    headers.extend(series.iter().map(|s| s.name.clone()));
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(title, &hdr_refs);
    if let Some(first) = series.first() {
        for (i, (x, _)) in first.points.iter().enumerate() {
            let mut row = vec![format!("{x:.5}")];
            for s in series {
                row.push(
                    s.points
                        .get(i)
                        .map(|(_, y)| format!("{y:.6}"))
                        .unwrap_or_default(),
                );
            }
            t.row(&row);
        }
    }
    t
}

// ---------------------------------------------------------------- Fig 1 --

/// Fig 1: roofline — theoretical attainable GFLOPS vs operational intensity
/// plus the simulated dense-GEMM ("cuBLAS") points, GTX980 and TitanX.
pub fn fig1_roofline() -> FigureOutput {
    let mut tables = Vec::new();
    for dev in [&simgpu::GTX980, &TITANX] {
        let mut theo = Series::new("roof_gflops");
        for (r, g) in crate::roofline::theoretical_curve(dev, 0.25, 256.0, 24) {
            theo.push(r, g);
        }
        let mut meas = Series::new("gemm_gflops");
        for n in [256usize, 512, 1024, 2048, 4096, 8192] {
            let (r, g) = crate::roofline::gemm_point(dev, n);
            meas.push(r, g);
        }
        let t1 = series_table(&format!("Fig 1 roofline ({})", dev.name), "r_flops_per_byte", &[theo]);
        let t2 = series_table(
            &format!("Fig 1 measured GEMM ({})", dev.name),
            "r_flops_per_byte",
            &[meas],
        );
        t1.write_csv(&format!("results/fig1_roof_{}.csv", dev.name));
        t2.write_csv(&format!("results/fig1_gemm_{}.csv", dev.name));
        tables.push(t1);
        tables.push(t2);
    }
    FigureOutput {
        tables,
        notes: vec![format!(
            "ridge points: GTX980 {:.1}, TitanX {:.1} FLOPs/byte",
            crate::roofline::ridge_point(&simgpu::GTX980),
            crate::roofline::ridge_point(&TITANX)
        )],
    }
}

// -------------------------------------------------------------- Table I --

/// Table I: memory consumption of CSR/COO/GCOO (+ dense, for the crossover).
pub fn table1_memory() -> FigureOutput {
    let mut t = Table::new(
        "Table I memory consumption (elements and bytes)",
        &["n", "sparsity", "p", "csr_elems", "coo_elems", "gcoo_elems", "gcoo_bytes", "dense_bytes"],
    );
    for &(n, s, p) in &[
        (1000usize, 0.9f64, 32usize),
        (4000, 0.98, 32),
        (4000, 0.995, 32),
        (14000, 0.995, 32),
        (14000, 0.995, 256),
    ] {
        let nnz = ((1.0 - s) * (n * n) as f64).round() as usize;
        t.row(&[
            n.to_string(),
            format!("{s}"),
            p.to_string(),
            sparse::csr_elements(nnz, n).to_string(),
            sparse::coo_elements(nnz).to_string(),
            sparse::gcoo_elements(nnz, n, p).to_string(),
            sparse::gcoo_bytes(nnz, n, p).total().to_string(),
            sparse::dense_bytes(n).total().to_string(),
        ]);
    }
    t.write_csv("results/table1_memory.csv");
    FigureOutput {
        tables: vec![t],
        notes: vec!["GCOO overhead vs COO is 2 elements per group (Table I)".into()],
    }
}

// ------------------------------------------------------------- Fig 4/6 ---

/// Shared histogram harness over a corpus of structural matrices.
fn ratio_histogram(entries: &[gen::CorpusEntry], dev: &DeviceConfig, cfg: &WalkConfig) -> (Histogram, Vec<f64>) {
    let mut h = Histogram::paper_ratio_bins();
    let mut ratios = Vec::with_capacity(entries.len());
    for e in entries {
        let a = e.materialize();
        let gcoo = Gcoo::from_dense(&a, 8);
        let st = GcooStructure::new(&gcoo);
        let g = simgpu::simulate_gcoo(&st, dev, cfg, true);
        let c = simgpu::simulate_csr(&st, dev, cfg);
        let ratio = c.time_s() / g.time_s(); // T_cuSPARSE / T_GCOOSpDM
        h.add(ratio);
        ratios.push(ratio);
    }
    (h, ratios)
}

fn hist_output(
    title_prefix: &str,
    entries: &[gen::CorpusEntry],
    csv_prefix: &str,
) -> FigureOutput {
    let cfg = WalkConfig::default();
    let mut tables = Vec::new();
    let mut notes = Vec::new();
    for dev in ALL_DEVICES {
        let (h, ratios) = ratio_histogram(entries, dev, &cfg);
        let mut t = Table::new(
            &format!("{title_prefix} ({}): T_cuSPARSE/T_GCOOSpDM histogram", dev.name),
            &["bin_start", "count"],
        );
        for (i, &c) in h.counts.iter().enumerate() {
            let label = if i < h.edges.len() - 1 {
                format!("{:.1}", h.edges[i])
            } else {
                "2.0+".to_string()
            };
            t.row(&[label, c.to_string()]);
        }
        t.write_csv(&format!("results/{csv_prefix}_{}.csv", dev.name));
        tables.push(t);
        let wins = ratios.iter().filter(|&&r| r > 1.0).count();
        let speedups: Vec<f64> = ratios.iter().copied().filter(|&r| r > 1.0).collect();
        let avg = if speedups.is_empty() {
            0.0
        } else {
            speedups.iter().sum::<f64>() / speedups.len() as f64
        };
        let max = ratios.iter().copied().fold(0.0, f64::max);
        notes.push(format!(
            "{}: GCOO wins {:.1}% of {} matrices; avg speedup {:.2}x, max {:.2}x",
            dev.name,
            100.0 * wins as f64 / ratios.len() as f64,
            ratios.len(),
            avg,
            max
        ));
    }
    FigureOutput { tables, notes }
}

/// Fig 4: histogram over the (synthetic stand-in for the) public dataset.
pub fn fig4_public_hist(count: usize, max_n: usize) -> FigureOutput {
    let spec = CorpusSpec { count, max_n, ..Default::default() };
    let entries = gen::corpus(&spec);
    hist_output("Fig 4 public-corpus", &entries, "fig4")
}

/// Fig 6: histogram over uniform random matrices (paper: 6968 matrices,
/// n ∈ [400, 14500], s ∈ [0.8, 0.9995]).
pub fn fig6_random_hist(count: usize, max_n: usize) -> FigureOutput {
    // Two sparsity ranges with the paper's densities of coverage.
    let mut rng = Rng::new(0xF16_6);
    let entries: Vec<gen::CorpusEntry> = (0..count)
        .map(|id| {
            let n = 400 + rng.index(max_n.saturating_sub(400).max(1));
            let sparsity = if rng.coin(0.75) {
                0.8 + rng.next_f64() * 0.195 // [0.8, 0.995)
            } else {
                0.995 + rng.next_f64() * 0.0045 // [0.995, 0.9995)
            };
            gen::CorpusEntry {
                id,
                pattern: gen::Pattern::Uniform,
                n,
                sparsity,
                seed: rng.next_u64(),
            }
        })
        .collect();
    hist_output("Fig 6 random-matrices", &entries, "fig6")
}

// ------------------------------------------------------- Table III/Fig 5 --

/// Fig 5 (+ Table III): effective GFLOPS per selected matrix on the P100.
pub fn fig5_selected(max_n: usize) -> FigureOutput {
    let cfg = WalkConfig::default();
    let mut t = Table::new(
        "Fig 5 selected matrices (P100): effective GFLOPS (Eq. 2)",
        &["matrix", "n", "density", "problem", "gcoo_gflops", "cusparse_gflops", "winner"],
    );
    let mut notes = Vec::new();
    for (spec, a) in gen::selected_matrices(max_n, 0xF15) {
        let gcoo = Gcoo::from_dense(&a, 8);
        let st = GcooStructure::new(&gcoo);
        let s = a.sparsity();
        let g = simgpu::simulate_gcoo(&st, &simgpu::P100, &cfg, true);
        let c = simgpu::simulate_csr(&st, &simgpu::P100, &cfg);
        let n = a.rows;
        let gg = g.effective_gflops(n, s);
        let cg = c.effective_gflops(n, s);
        let winner = if gg >= cg { "gcoo" } else { "cusparse" };
        if spec.expected_gcoo_loss() && winner == "cusparse" {
            notes.push(format!("{}: loss case reproduced (diagonal structure)", spec.name));
        }
        t.row(&[
            spec.name.to_string(),
            n.to_string(),
            format!("{:.2e}", spec.density),
            spec.problem.to_string(),
            format!("{gg:.2}"),
            format!("{cg:.2}"),
            winner.to_string(),
        ]);
    }
    t.write_csv("results/fig5_selected.csv");
    FigureOutput { tables: vec![t], notes }
}

// ------------------------------------------------------------ Figs 7-9 ---

/// Figs 7–9: time vs sparsity at n ∈ {4000, 14000} on all three devices,
/// including the dense (cuBLAS) constant line.
pub fn fig7_9_time_vs_sparsity() -> FigureOutput {
    let cfg = WalkConfig::default();
    let sweep: Vec<f64> = vec![0.95, 0.96, 0.97, 0.98, 0.99, 0.995, 0.999, 0.9995];
    let mut tables = Vec::new();
    let mut notes = Vec::new();
    for dev in ALL_DEVICES {
        for &n in &[4000usize, 14000] {
            let mut s_g = Series::new("gcoo_ms");
            let mut s_c = Series::new("cusparse_ms");
            let mut s_d = Series::new("cublas_ms");
            let dense = simgpu::simulate_dense(n, dev, &cfg).time_s() * 1e3;
            let mut gcoo_cross = None;
            let mut csr_cross = None;
            for &s in &sweep {
                let st = SyntheticUniform::new(n, s, 8, 0x719);
                let g = simgpu::simulate_gcoo(&st, dev, &cfg, true).time_s() * 1e3;
                let c = simgpu::simulate_csr(&st, dev, &cfg).time_s() * 1e3;
                if g < dense && gcoo_cross.is_none() {
                    gcoo_cross = Some(s);
                }
                if c < dense && csr_cross.is_none() {
                    csr_cross = Some(s);
                }
                s_g.push(s, g);
                s_c.push(s, c);
                s_d.push(s, dense);
            }
            let t = series_table(
                &format!("Figs 7-9 time vs sparsity ({}, n={n})", dev.name),
                "sparsity",
                &[s_g, s_c, s_d],
            );
            t.write_csv(&format!("results/fig7_9_{}_n{n}.csv", dev.name));
            tables.push(t);
            notes.push(format!(
                "{} n={n}: gcoo beats dense from s≈{:?}, csr from s≈{:?} (paper: 0.98 / 0.995)",
                dev.name, gcoo_cross, csr_cross
            ));
        }
    }
    FigureOutput { tables, notes }
}

// ---------------------------------------------------------- Figs 10-12 ---

/// Figs 10–12: effective GFLOPS vs n at s ∈ {0.98, 0.995}.
pub fn fig10_12_perf_vs_size() -> FigureOutput {
    let cfg = WalkConfig::default();
    let sizes: Vec<usize> = vec![400, 800, 1500, 2000, 4000, 6000, 8000, 10000, 14000];
    let mut tables = Vec::new();
    for dev in ALL_DEVICES {
        for &s in &[0.98f64, 0.995] {
            let mut s_g = Series::new("gcoo_gflops");
            let mut s_c = Series::new("cusparse_gflops");
            let mut s_d = Series::new("cublas_gflops");
            for &n in &sizes {
                let st = SyntheticUniform::new(n, s, 8, 0x1012);
                let g = simgpu::simulate_gcoo(&st, dev, &cfg, true);
                let c = simgpu::simulate_csr(&st, dev, &cfg);
                let d = simgpu::simulate_dense(n, dev, &cfg);
                s_g.push(n as f64, g.effective_gflops(n, s));
                s_c.push(n as f64, c.effective_gflops(n, s));
                // dense "effective" GFLOPS uses the same useful-FLOP count
                s_d.push(n as f64, 2.0 * (n as f64).powi(3) * (1.0 - s) / d.time_s() / 1e9);
            }
            let t = series_table(
                &format!("Figs 10-12 perf vs size ({}, s={s})", dev.name),
                "n",
                &[s_g, s_c, s_d],
            );
            t.write_csv(&format!("results/fig10_12_{}_s{s}.csv", dev.name));
            tables.push(t);
        }
    }
    FigureOutput {
        tables,
        notes: vec!["paper check: gcoo ≈ cublas at s=0.98, gcoo up to 2x cublas at 0.995".into()],
    }
}

// -------------------------------------------------------------- Fig 13 ---

/// Fig 13: EO (alloc + conversion) vs KC (kernel) breakdown on the TitanX.
/// Conversion is modeled as bandwidth-bound (read n²·4B, write nnz·12B) —
/// the same cost lens as the kernels — and cross-checked against measured
/// CPU conversion on small n (second table).
///
/// KC times come from traced kernel execution: `simulate_gcoo`/`simulate_csr`
/// replay the kernels' memory-event streams (DESIGN.md §Tracing) through the
/// device model, so this figure shares its provenance with the instrumented
/// serving path rather than a separate hand-maintained walker.
pub fn fig13_breakdown() -> FigureOutput {
    let cfg = WalkConfig::default();
    let dev = &TITANX;
    let mut t = Table::new(
        "Fig 13 time breakdown (TitanX, simulated)",
        &["n", "sparsity", "algo", "eo_ms", "kc_ms", "eo_fraction"],
    );
    for &n in &[4000usize, 14000] {
        for &s in &[0.95f64, 0.96, 0.97, 0.98, 0.99] {
            let nnz = ((n * n) as f64 * (1.0 - s)) as u64;
            let eo = ((n * n) as f64 * 4.0 + nnz as f64 * 12.0) / dev.dram_bw() * 1e3
                + 2.0 * dev.launch_overhead_s * 1e3;
            let st = SyntheticUniform::new(n, s, 8, 0xF13);
            for (algo, kc) in [
                ("gcoo", simgpu::simulate_gcoo(&st, dev, &cfg, true).time_s() * 1e3),
                ("cusparse", simgpu::simulate_csr(&st, dev, &cfg).time_s() * 1e3),
            ] {
                t.row(&[
                    n.to_string(),
                    format!("{s}"),
                    algo.into(),
                    format!("{eo:.3}"),
                    format!("{kc:.3}"),
                    format!("{:.3}", eo / (eo + kc)),
                ]);
            }
        }
    }
    t.write_csv("results/fig13_breakdown.csv");

    // Measured CPU conversion EO (real Algorithm 1 implementation).
    let mut t2 = Table::new(
        "Fig 13 cross-check: measured CPU conversion (this testbed)",
        &["n", "sparsity", "alloc_ms", "convert_ms"],
    );
    for &n in &[1024usize, 2048] {
        for &s in &[0.95f64, 0.99] {
            let mut rng = Rng::new(0x13B);
            let a = gen::uniform(n, s, &mut rng);
            let (_g, timing) = convert::dense_to_gcoo_parallel(&a, 8, 4);
            t2.row(&[
                n.to_string(),
                format!("{s}"),
                format!("{:.3}", timing.alloc_s * 1e3),
                format!("{:.3}", timing.convert_s * 1e3),
            ]);
        }
    }
    t2.write_csv("results/fig13_measured_conversion.csv");
    FigureOutput {
        tables: vec![t, t2],
        notes: vec!["paper check: EO is a small fraction of total; KC dominates".into()],
    }
}

// -------------------------------------------------------------- Fig 14 ---

/// Fig 14: instruction (transaction) distributions vs n and vs s, TitanX.
///
/// Counter provenance is traced execution: the per-class transaction counts
/// are the replayed memory-event streams of the kernels (DESIGN.md §Tracing),
/// i.e. the same events the instrumented serving path emits, classified by
/// the device model's cache hierarchy. The trailing `*_share` columns are the
/// per-class fractions of all memory transactions (they sum to 1.0 whenever
/// any transaction was issued — the nvprof-style normalized view).
pub fn fig14_instructions() -> FigureOutput {
    let cfg = WalkConfig::default();
    let dev = &TITANX;
    let mut tables = Vec::new();

    let counter_headers = [
        "n_dram",
        "n_l2",
        "n_shm",
        "tex_l1_trans",
        "dram_share",
        "l2_share",
        "shm_share",
        "tex_share",
    ];
    let counter_cells = |c: &simgpu::Counters| -> Vec<String> {
        let sh = c.shares();
        vec![
            c.dram.to_string(),
            c.l2.to_string(),
            c.shm.to_string(),
            c.l1_tex.to_string(),
            format!("{:.6}", sh[0]),
            format!("{:.6}", sh[1]),
            format!("{:.6}", sh[2]),
            format!("{:.6}", sh[3]),
        ]
    };

    // vs n at s = 0.995
    let sizes = [500usize, 1000, 2000, 4000, 6000, 8000, 10000];
    for (algo_name, is_gcoo) in [("cusparse", false), ("gcoo", true)] {
        let mut headers = vec!["n"];
        headers.extend(counter_headers);
        let mut t = Table::new(
            &format!("Fig 14 transactions vs n (s=0.995, {algo_name}, TitanX)"),
            &headers,
        );
        for &n in &sizes {
            let st = SyntheticUniform::new(n, 0.995, 8, 0xF14);
            let c = if is_gcoo {
                simgpu::simulate_gcoo(&st, dev, &cfg, true).counters
            } else {
                simgpu::simulate_csr(&st, dev, &cfg).counters
            };
            let mut row = vec![n.to_string()];
            row.extend(counter_cells(&c));
            t.row(&row);
        }
        t.write_csv(&format!("results/fig14_vs_n_{algo_name}.csv"));
        tables.push(t);
    }

    // vs s at n = 4000
    let sweep = [0.8f64, 0.9, 0.95, 0.98, 0.99, 0.995, 0.999, 0.9995];
    for (algo_name, is_gcoo) in [("cusparse", false), ("gcoo", true)] {
        let mut headers = vec!["sparsity"];
        headers.extend(counter_headers);
        let mut t = Table::new(
            &format!("Fig 14 transactions vs sparsity (n=4000, {algo_name}, TitanX)"),
            &headers,
        );
        for &s in &sweep {
            let st = SyntheticUniform::new(4000, s, 8, 0xF14);
            let c = if is_gcoo {
                simgpu::simulate_gcoo(&st, dev, &cfg, true).counters
            } else {
                simgpu::simulate_csr(&st, dev, &cfg).counters
            };
            let mut row = vec![format!("{s}")];
            row.extend(counter_cells(&c));
            t.row(&row);
        }
        t.write_csv(&format!("results/fig14_vs_s_{algo_name}.csv"));
        tables.push(t);
    }

    // Supplement: dense-vs-gcoo DRAM traffic per Table II device. The paper's
    // §IV.C observation is that the dense kernel moves the whole n² operand
    // through DRAM while GCOO touches only the nnz structure plus gathered B
    // columns — so at high sparsity gcoo's DRAM transactions sit strictly
    // below dense's on every device.
    let mut t_dram = Table::new(
        "Fig 14 supplement: DRAM transactions, gcoo vs dense (n=1024, s=0.999)",
        &["device", "gcoo_dram", "dense_dram"],
    );
    for sup_dev in ALL_DEVICES {
        let st = SyntheticUniform::new(1024, 0.999, 8, 0xF14);
        let g = simgpu::simulate_gcoo(&st, sup_dev, &cfg, true).counters;
        let d = simgpu::simulate_dense(1024, sup_dev, &cfg).counters;
        t_dram.row(&[sup_dev.name.to_string(), g.dram.to_string(), d.dram.to_string()]);
    }
    t_dram.write_csv("results/fig14_dram_gcoo_vs_dense.csv");
    tables.push(t_dram);

    FigureOutput {
        tables,
        notes: vec![
            "paper check: cuSPARSE dominated by n_l2; GCOO splits l2/shm/tex ≈ evenly".into(),
            "paper check: dram transactions are a small share for both".into(),
            "paper check: gcoo DRAM < dense DRAM at high sparsity on every device".into(),
        ],
    }
}

// -------------------------------------------------------------- Fig 15 ---

/// Fig 15: kernel-time scaling vs n and vs s (cuSPARSE vs GCOOSpDM, TitanX).
pub fn fig15_scaling() -> FigureOutput {
    let cfg = WalkConfig::default();
    let dev = &TITANX;
    let mut s_gn = Series::new("gcoo_ms");
    let mut s_cn = Series::new("cusparse_ms");
    for &n in &[500usize, 1000, 2000, 4000, 6000, 8000, 10000] {
        let st = SyntheticUniform::new(n, 0.995, 8, 0xF15);
        s_gn.push(n as f64, simgpu::simulate_gcoo(&st, dev, &cfg, true).time_s() * 1e3);
        s_cn.push(n as f64, simgpu::simulate_csr(&st, dev, &cfg).time_s() * 1e3);
    }
    let t1 = series_table("Fig 15 time vs n (s=0.995, TitanX)", "n", &[s_gn, s_cn]);
    t1.write_csv("results/fig15_vs_n.csv");

    let mut s_gs = Series::new("gcoo_ms");
    let mut s_cs = Series::new("cusparse_ms");
    for &s in &[0.8f64, 0.9, 0.95, 0.98, 0.99, 0.995, 0.999, 0.9995] {
        let st = SyntheticUniform::new(4000, s, 8, 0xF15);
        s_gs.push(s, simgpu::simulate_gcoo(&st, dev, &cfg, true).time_s() * 1e3);
        s_cs.push(s, simgpu::simulate_csr(&st, dev, &cfg).time_s() * 1e3);
    }
    let t2 = series_table("Fig 15 time vs sparsity (n=4000, TitanX)", "sparsity", &[s_gs, s_cs]);
    t2.write_csv("results/fig15_vs_s.csv");
    FigureOutput {
        tables: vec![t1, t2],
        notes: vec!["paper check: ~quadratic growth in n; cuSPARSE ~quadratic, GCOO ~linear decrease in s".into()],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_rows_and_formulas_hold() {
        let out = table1_memory();
        assert_eq!(out.tables.len(), 1);
        assert!(out.tables[0].rows.len() >= 5);
    }

    #[test]
    fn fig1_produces_both_devices() {
        let out = fig1_roofline();
        assert_eq!(out.tables.len(), 4);
        assert!(out.notes[0].contains("ridge"));
    }

    #[test]
    fn fig4_small_corpus_runs() {
        let out = fig4_public_hist(12, 256);
        assert_eq!(out.tables.len(), 3); // three devices
        let total: u64 = out.tables[0].rows.iter().map(|r| r[1].parse::<u64>().unwrap()).sum();
        assert_eq!(total, 12);
    }

    #[test]
    fn fig5_reports_all_14() {
        let out = fig5_selected(256);
        assert_eq!(out.tables[0].rows.len(), 14);
    }

    #[test]
    fn fig14_gcoo_uses_shm_cusparse_does_not() {
        let out = fig14_instructions();
        // tables: [vs_n cusparse, vs_n gcoo, vs_s cusparse, vs_s gcoo, dram supplement]
        let cus = &out.tables[0];
        let gco = &out.tables[1];
        for row in &cus.rows {
            assert_eq!(row[4], "0", "cusparse tex_l1 must be 0");
        }
        for row in &gco.rows {
            assert!(row[3].parse::<u64>().unwrap() > 0, "gcoo shm must be > 0");
        }
    }

    /// Golden check: the transaction-class shares appended to every Fig 14
    /// row are a proper distribution — they sum to 1.0 whenever any memory
    /// transaction was issued (traced replay never produces an all-zero
    /// counter set for a non-empty kernel).
    #[test]
    fn fig14_shares_sum_to_one() {
        let cfg = WalkConfig::default();
        for dev in ALL_DEVICES {
            let st = SyntheticUniform::new(1024, 0.995, 8, 0xF14);
            for c in [
                simgpu::simulate_gcoo(&st, dev, &cfg, true).counters,
                simgpu::simulate_csr(&st, dev, &cfg).counters,
                simgpu::simulate_dense(1024, dev, &cfg).counters,
            ] {
                assert!(c.total_mem_transactions() > 0, "{}: empty counters", dev.name);
                let sum: f64 = c.shares().iter().sum();
                assert!(
                    (sum - 1.0).abs() < 1e-9,
                    "{}: shares sum to {sum}, not 1.0",
                    dev.name
                );
            }
        }
        // And the rendered tables carry the same invariant in their last
        // four columns.
        let out = fig14_instructions();
        for t in &out.tables[..4] {
            let w = t.headers.len();
            for row in &t.rows {
                let sum: f64 = row[w - 4..].iter().map(|s| s.parse::<f64>().unwrap()).sum();
                assert!((sum - 1.0).abs() < 1e-3, "{}: row shares sum {sum}", t.title);
            }
        }
    }

    /// Golden check: the paper's dense-vs-gcoo DRAM asymmetry (§IV.C) has
    /// the right sign on every Table II device — at high sparsity the GCOO
    /// kernel issues strictly fewer DRAM transactions than the dense GEMM,
    /// which must stream the full n² operand.
    #[test]
    fn fig14_dram_asymmetry_sign_on_all_devices() {
        let cfg = WalkConfig::default();
        for dev in ALL_DEVICES {
            let st = SyntheticUniform::new(1024, 0.999, 8, 0xF14);
            let g = simgpu::simulate_gcoo(&st, dev, &cfg, true).counters;
            let d = simgpu::simulate_dense(1024, dev, &cfg).counters;
            assert!(
                g.dram < d.dram,
                "{}: gcoo dram {} must be < dense dram {}",
                dev.name,
                g.dram,
                d.dram
            );
        }
    }

    /// Golden check: Fig 13's EO fraction is a proper fraction — conversion
    /// overhead is real (eo > 0) but the kernel dominates at these scales.
    #[test]
    fn fig13_eo_fraction_bounded() {
        let out = fig13_breakdown();
        let t = &out.tables[0];
        let w = t.headers.len();
        assert!(!t.rows.is_empty());
        for row in &t.rows {
            let f: f64 = row[w - 1].parse().unwrap();
            assert!(f > 0.0 && f < 1.0, "eo_fraction {f} outside (0,1)");
        }
    }
}
