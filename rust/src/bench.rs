//! Measurement harness substrate (the offline image has no criterion).
//!
//! `cargo bench` targets use [`Bencher`] for timed closures and
//! [`Series`]/[`Table`] to print the paper-style rows each bench regenerates,
//! plus CSV dumps under results/ so EXPERIMENTS.md numbers are reproducible.

use std::fmt::Write as _;
use std::time::{Duration, Instant};

/// Robust statistics over a set of timing samples (seconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub samples: Vec<f64>,
}

impl Stats {
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn median(&self) -> f64 {
        crate::ndarray::percentile(&self.samples, 50.0)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(0.0, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / self.samples.len() as f64;
        var.sqrt()
    }

    /// Median absolute deviation — robust spread estimate.
    pub fn mad(&self) -> f64 {
        let med = self.median();
        let devs: Vec<f64> = self.samples.iter().map(|x| (x - med).abs()).collect();
        crate::ndarray::percentile(&devs, 50.0)
    }
}

/// Timed-measurement runner: warmup then fixed-count or time-budgeted sampling.
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub time_budget: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            min_iters: 5,
            max_iters: 100,
            time_budget: Duration::from_secs(2),
        }
    }
}

impl Bencher {
    /// Quick preset for slow end-to-end cases.
    pub fn quick() -> Self {
        Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            time_budget: Duration::from_millis(500),
        }
    }

    /// Measure `f` (its return value is passed to a sink to prevent DCE).
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::new();
        let started = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && started.elapsed() < self.time_budget)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        Stats { samples }
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// A named series of (x, y) points — one line in a paper figure.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str) -> Self {
        Series { name: name.to_string(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
}

/// Fixed-width text table mirroring a paper table/figure's rows.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (c, cell) in cells.iter().enumerate() {
                let _ = write!(s, "{:<w$}  ", cell, w = widths[c]);
            }
            s.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Write as CSV into results/ (best-effort; benches must not fail on IO).
    pub fn write_csv(&self, path: &str) {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        if let Some(parent) = std::path::Path::new(path).parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        let _ = std::fs::write(path, out);
    }
}

/// Histogram with fixed bin edges — the paper's Fig 4/6 presentation.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub edges: Vec<f64>,   // len = bins + 1; last bin is open-ended
    pub counts: Vec<u64>,  // len = bins
}

impl Histogram {
    /// Paper Fig 4/6 bins: [0,0.2), [0.2,0.4), …, [1.8,2.0), [2.0, ∞).
    pub fn paper_ratio_bins() -> Self {
        let edges: Vec<f64> = (0..=10).map(|i| i as f64 * 0.2).collect();
        let counts = vec![0; edges.len()]; // last = 2.0+
        Histogram { edges, counts }
    }

    pub fn add(&mut self, x: f64) {
        for i in 0..self.edges.len() - 1 {
            if x >= self.edges[i] && x < self.edges[i + 1] {
                self.counts[i] += 1;
                return;
            }
        }
        *self.counts.last_mut().unwrap() += 1; // open-ended final bin
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Fraction of samples at or above `threshold`'s bin start.
    pub fn frac_at_least(&self, threshold: f64) -> f64 {
        let total = self.total() as f64;
        // counts[i] pairs with edges[i] as its bin start; the final count is
        // the open-ended bin starting at the last edge.
        let sum: u64 = self
            .edges
            .iter()
            .zip(&self.counts)
            .filter(|(e, _)| **e >= threshold - 1e-12)
            .map(|(_, c)| *c)
            .sum::<u64>();
        sum as f64 / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_collects_min_iters() {
        let b = Bencher { warmup_iters: 0, min_iters: 4, max_iters: 8, time_budget: Duration::ZERO };
        let stats = b.run(|| 1 + 1);
        assert!(stats.samples.len() >= 4);
        assert!(stats.samples.len() <= 8);
    }

    #[test]
    fn stats_basics() {
        let s = Stats { samples: vec![1.0, 2.0, 3.0, 4.0] };
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.median(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!(s.stddev() > 0.0);
        assert_eq!(s.mad(), 1.0);
    }

    #[test]
    fn table_renders_all_rows() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("333"));
        assert_eq!(r.lines().count(), 5);
    }

    #[test]
    #[should_panic]
    fn table_row_width_checked() {
        let mut t = Table::new("demo", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn histogram_paper_bins() {
        let mut h = Histogram::paper_ratio_bins();
        h.add(0.1);   // [0, .2)
        h.add(1.95);  // [1.8, 2)
        h.add(2.5);   // 2.0+
        h.add(7.0);   // 2.0+
        assert_eq!(h.total(), 4);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[9], 1);
        assert_eq!(*h.counts.last().unwrap(), 2);
        assert!((h.frac_at_least(2.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_frac_at_least_one() {
        let mut h = Histogram::paper_ratio_bins();
        for x in [0.5, 1.1, 1.3, 2.2] {
            h.add(x);
        }
        assert!((h.frac_at_least(1.0) - 0.75).abs() < 1e-12);
    }
}
