//! Runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`) via the `xla`
//! crate's PJRT CPU client and executes them from the request path.
//!
//! Python never runs here: the manifest + HLO text files are the entire
//! interface between the build path and this layer.

mod registry;
mod engine;

pub use registry::{ArtifactMeta, InputSpec, Registry};
pub use engine::{Engine, SpdmOutput};

/// Errors from the runtime layer.
#[derive(Debug)]
pub enum RuntimeError {
    /// manifest.json missing/invalid or artifact file unreadable.
    Manifest(String),
    /// No compiled variant can serve the request.
    NoVariant { algo: String, n: usize, needed_cap: usize },
    /// PJRT/XLA failure.
    Xla(String),
    /// Input shape does not match the artifact.
    Shape(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Manifest(m) => write!(f, "manifest error: {m}"),
            RuntimeError::NoVariant { algo, n, needed_cap } => {
                write!(f, "no {algo} artifact for n={n} cap>={needed_cap}")
            }
            RuntimeError::Xla(m) => write!(f, "xla error: {m}"),
            RuntimeError::Shape(m) => write!(f, "shape error: {m}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}
