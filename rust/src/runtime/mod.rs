//! Runtime — loads the AOT artifacts (`artifacts/*.hlo.txt` + manifest.json)
//! and executes them from the request path.
//!
//! Python never runs here: the manifest + artifact files are the entire
//! interface between the build path and this layer. In the offline build
//! image the PJRT/XLA client is unavailable, so [`Engine`] executes each
//! artifact with a reference CPU kernel dispatched on the artifact's `algo`
//! (DESIGN.md §2) while keeping the PJRT engine's observable contract:
//! artifacts must exist on disk, loads are cached, timings are logged.

mod registry;
mod engine;
mod plan;

pub use registry::{ArtifactMeta, InputSpec, Registry};
pub use engine::{CopyStats, DeviceOperand, Engine, ExecStats, SpdmOutput};
pub use plan::{Algo, ExecPlan};

/// Errors from the runtime layer.
#[derive(Debug)]
pub enum RuntimeError {
    /// manifest.json missing/invalid or artifact file unreadable.
    Manifest(String),
    /// No compiled variant can serve the request.
    NoVariant { algo: String, n: usize, needed_cap: usize },
    /// Executor failure (artifact unreadable / backend error).
    Exec(String),
    /// Input shape does not match the artifact.
    Shape(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Manifest(m) => write!(f, "manifest error: {m}"),
            RuntimeError::NoVariant { algo, n, needed_cap } => {
                write!(f, "no {algo} artifact for n={n} cap>={needed_cap}")
            }
            RuntimeError::Exec(m) => write!(f, "executor error: {m}"),
            RuntimeError::Shape(m) => write!(f, "shape error: {m}"),
        }
    }
}

impl std::error::Error for RuntimeError {}
