//! Engine: PJRT CPU client + compile cache + typed SpDM execution helpers.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos carry 64-bit instruction ids that this xla_extension
//! (0.5.1) rejects; the text parser reassigns ids (see aot recipe notes in
//! /opt/xla-example/README.md).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use super::{ArtifactMeta, Registry, RuntimeError};
use crate::ndarray::Mat;
use crate::sparse::{Ell, GcooPadded};

/// Result of one executed SpDM: the product and the kernel wall time.
#[derive(Clone, Debug)]
pub struct SpdmOutput {
    pub c: Mat,
    pub kernel_s: f64,
    pub artifact: String,
}

/// PJRT client with a per-artifact compile cache. `Send + Sync` via the
/// internal mutex; one engine is shared by all coordinator workers.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// compile timings per artifact (observability; tests assert caching).
    compile_log: Mutex<Vec<(String, f64)>>,
}

impl Engine {
    pub fn new() -> Result<Engine, RuntimeError> {
        Ok(Engine {
            client: xla::PjRtClient::cpu()?,
            cache: Mutex::new(HashMap::new()),
            compile_log: Mutex::new(Vec::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached).
    pub fn load(
        &self,
        meta: &ArtifactMeta,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>, RuntimeError> {
        if let Some(exe) = self.cache.lock().unwrap().get(&meta.name) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&meta.file)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.compile_log
            .lock()
            .unwrap()
            .push((meta.name.clone(), t0.elapsed().as_secs_f64()));
        self.cache.lock().unwrap().insert(meta.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Number of distinct artifacts compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    pub fn compile_log(&self) -> Vec<(String, f64)> {
        self.compile_log.lock().unwrap().clone()
    }

    /// Execute an artifact on literal inputs; unwraps the 1-tuple output
    /// into an (n, n) matrix.
    fn execute(
        &self,
        meta: &ArtifactMeta,
        inputs: &[xla::Literal],
    ) -> Result<SpdmOutput, RuntimeError> {
        let exe = self.load(meta)?;
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let kernel_s = t0.elapsed().as_secs_f64();
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f32>()?;
        if data.len() != meta.n * meta.n {
            return Err(RuntimeError::Shape(format!(
                "{}: output length {} != {}²",
                meta.name,
                data.len(),
                meta.n
            )));
        }
        Ok(SpdmOutput {
            c: Mat::from_vec(meta.n, meta.n, data),
            kernel_s,
            artifact: meta.name.clone(),
        })
    }

    /// Run GCOOSpDM: pick the artifact from `reg`, check shapes, execute.
    pub fn run_gcoo(
        &self,
        reg: &Registry,
        padded: &GcooPadded,
        b: &Mat,
        reuse: bool,
    ) -> Result<SpdmOutput, RuntimeError> {
        let algo = if reuse { "gcoo" } else { "gcoo_noreuse" };
        let n = b.rows;
        let meta = reg.select(algo, n, padded.cap)?;
        let cap = meta.param("cap").expect("gcoo artifact has cap");
        // Re-pad if the artifact's cap differs from the provided padding.
        let (vals, rows, cols) = if cap == padded.cap {
            (padded.vals.clone(), padded.rows.clone(), padded.cols.clone())
        } else {
            repad(padded, cap)
        };
        check(b.rows == meta.n && b.cols == meta.n, || {
            format!("B is {}x{}, artifact n={}", b.rows, b.cols, meta.n)
        })?;
        check(padded.g * padded.p == meta.n, || {
            format!("A bands {}x{} != n={}", padded.g, padded.p, meta.n)
        })?;
        let g = padded.g;
        let lits = vec![
            lit_f32(&vals, &[g, cap])?,
            lit_i32(&rows, &[g, cap])?,
            lit_i32(&cols, &[g, cap])?,
            lit_f32(&b.data, &[n, n])?,
        ];
        self.execute(meta, &lits)
    }

    /// Run the CSR (cuSPARSE-analog) kernel.
    pub fn run_csr(&self, reg: &Registry, ell: &Ell, b: &Mat) -> Result<SpdmOutput, RuntimeError> {
        let n = b.rows;
        let meta = reg.select("csr", n, ell.rowcap)?;
        let rowcap = meta.param("rowcap").expect("csr artifact has rowcap");
        let (vals, cols) = if rowcap == ell.rowcap {
            (ell.vals.clone(), ell.cols.clone())
        } else {
            repad_ell(ell, rowcap)
        };
        check(ell.n == meta.n && b.rows == meta.n && b.cols == meta.n, || {
            format!("shape mismatch: ell.n={} b={}x{} n={}", ell.n, b.rows, b.cols, meta.n)
        })?;
        let lits = vec![
            lit_f32(&vals, &[n, rowcap])?,
            lit_i32(&cols, &[n, rowcap])?,
            lit_f32(&b.data, &[n, n])?,
        ];
        self.execute(meta, &lits)
    }

    /// Run the GCOO SpMV extension kernel: y = A·x (paper future work).
    pub fn run_gcoo_spmv(
        &self,
        reg: &Registry,
        padded: &GcooPadded,
        x: &[f32],
    ) -> Result<(Vec<f32>, f64, String), RuntimeError> {
        let n = x.len();
        let meta = reg.select("gcoo_spmv", n, padded.cap)?;
        let cap = meta.param("cap").expect("spmv artifact has cap");
        let (vals, rows, cols) = if cap == padded.cap {
            (padded.vals.clone(), padded.rows.clone(), padded.cols.clone())
        } else {
            repad(padded, cap)
        };
        check(padded.g * padded.p == meta.n && n == meta.n, || {
            format!("spmv shapes: A bands {}x{}, x len {}, artifact n={}", padded.g, padded.p, n, meta.n)
        })?;
        let g = padded.g;
        let lits = vec![
            lit_f32(&vals, &[g, cap])?,
            lit_i32(&rows, &[g, cap])?,
            lit_i32(&cols, &[g, cap])?,
            lit_f32(x, &[n])?,
        ];
        let exe = self.load(meta)?;
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let kernel_s = t0.elapsed().as_secs_f64();
        let out = result.to_tuple1()?;
        let y = out.to_vec::<f32>()?;
        check(y.len() == n, || format!("spmv output {} != {}", y.len(), n))?;
        Ok((y, kernel_s, meta.name.clone()))
    }

    /// Run a dense baseline ("dense_xla" = the vendor GEMM, "dense_pallas"
    /// = the explicit tiled kernel).
    pub fn run_dense(
        &self,
        reg: &Registry,
        algo: &str,
        a: &Mat,
        b: &Mat,
    ) -> Result<SpdmOutput, RuntimeError> {
        let n = b.rows;
        let meta = reg.select(algo, n, 0)?;
        check(a.rows == n && a.cols == n && b.cols == n, || {
            format!("dense shapes {}x{} / {}x{}", a.rows, a.cols, b.rows, b.cols)
        })?;
        let lits = vec![lit_f32(&a.data, &[n, n])?, lit_f32(&b.data, &[n, n])?];
        self.execute(meta, &lits)
    }
}

fn check(ok: bool, msg: impl FnOnce() -> String) -> Result<(), RuntimeError> {
    if ok {
        Ok(())
    } else {
        Err(RuntimeError::Shape(msg()))
    }
}

fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal, RuntimeError> {
    let expect: usize = dims.iter().product();
    if data.len() != expect {
        return Err(RuntimeError::Shape(format!("f32 literal {} != {:?}", data.len(), dims)));
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal, RuntimeError> {
    let expect: usize = dims.iter().product();
    if data.len() != expect {
        return Err(RuntimeError::Shape(format!("i32 literal {} != {:?}", data.len(), dims)));
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// Re-pad device GCOO slabs to a different capacity.
fn repad(p: &GcooPadded, cap: usize) -> (Vec<f32>, Vec<i32>, Vec<i32>) {
    let mut vals = vec![0.0f32; p.g * cap];
    let mut rows = vec![0i32; p.g * cap];
    let mut cols = vec![0i32; p.g * cap];
    let copy = p.cap.min(cap);
    for gi in 0..p.g {
        vals[gi * cap..gi * cap + copy].copy_from_slice(&p.vals[gi * p.cap..gi * p.cap + copy]);
        rows[gi * cap..gi * cap + copy].copy_from_slice(&p.rows[gi * p.cap..gi * p.cap + copy]);
        cols[gi * cap..gi * cap + copy].copy_from_slice(&p.cols[gi * p.cap..gi * p.cap + copy]);
    }
    (vals, rows, cols)
}

fn repad_ell(e: &Ell, rowcap: usize) -> (Vec<f32>, Vec<i32>) {
    let mut vals = vec![0.0f32; e.n * rowcap];
    let mut cols = vec![0i32; e.n * rowcap];
    let copy = e.rowcap.min(rowcap);
    for i in 0..e.n {
        vals[i * rowcap..i * rowcap + copy]
            .copy_from_slice(&e.vals[i * e.rowcap..i * e.rowcap + copy]);
        cols[i * rowcap..i * rowcap + copy]
            .copy_from_slice(&e.cols[i * e.rowcap..i * e.rowcap + copy]);
    }
    (vals, cols)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repad_grows_and_shrinks_consistently() {
        let p = GcooPadded {
            g: 2,
            cap: 2,
            p: 2,
            n: 4,
            vals: vec![1.0, 2.0, 3.0, 4.0],
            rows: vec![0, 1, 0, 1],
            cols: vec![0, 1, 2, 3],
        };
        let (v, r, c) = repad(&p, 3);
        assert_eq!(v, vec![1.0, 2.0, 0.0, 3.0, 4.0, 0.0]);
        assert_eq!(r, vec![0, 1, 0, 0, 1, 0]);
        assert_eq!(c, vec![0, 1, 0, 2, 3, 0]);
    }

    #[test]
    fn repad_ell_grows() {
        let e = Ell { n: 2, rowcap: 1, vals: vec![5.0, 6.0], cols: vec![1, 0] };
        let (v, c) = repad_ell(&e, 2);
        assert_eq!(v, vec![5.0, 0.0, 6.0, 0.0]);
        assert_eq!(c, vec![1, 0, 0, 0]);
    }

    // Engine tests that need a PJRT client + real artifacts live in
    // rust/tests/runtime_integration.rs (they require `make artifacts`).
}
