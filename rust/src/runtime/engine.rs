//! Engine: artifact loader + compile cache + typed SpDM execution helpers.
//!
//! The offline build image has no PJRT/XLA runtime (DESIGN.md §2), so
//! execution is provided by the substrate: each artifact's computation is
//! carried out by a reference CPU kernel dispatched on the artifact's
//! `algo`, operating on exactly the device-layout arrays the AOT executable
//! would consume (padded GCOO slabs, ELL slabs, row-major dense). The
//! observable engine behavior is preserved: artifacts must exist on disk to
//! load, loading is cached per artifact name, and `compile_log` records
//! load/compile timings — so the registry routing, capacity re-padding and
//! caching logic upstream is exercised for real.
//!
//! Execution consumes **borrowed slab views** (`GcooSlabs`/`EllSlabs`):
//! every shape check runs before any slab materialization (cheap-fail
//! first), and slabs are only copied when the artifact's capacity differs
//! from the provided one — the matching-cap path is a true zero-copy
//! borrow, accounted in each output's [`CopyStats`].
//!
//! The SpDM entry points accept a **wide B**: the dense operand may be
//! `n × w·n` for any batch width `w ≥ 1` (the coordinator stacks a fused
//! batch's B matrices column-wise), with the artifact still selected by
//! `n = b.rows`. The `_into` variants write C into a caller-owned buffer
//! (`Mat::zero_into`, allocation reused across calls) so per-worker
//! workspaces can stage the wide result without a per-batch allocation.
//!
//! **Instrumented execution** (DESIGN.md §Tracing): every SpDM entry point
//! has a `_sink` variant generic over [`TraceSink`] that, while computing
//! the real product, emits the kernel's warp-level memory-event stream
//! (via the shared `simgpu::trace` emitters) in GPU launch order — so
//! simgpu's model consumes what the kernels *produce* instead of a
//! hand-maintained second description. The plain entry points delegate
//! with [`NullSink`]; since the sink type is monomorphized and emission is
//! gated on `sink.active()`, the disabled path is the exact
//! pre-instrumentation code: same kernels, same outputs, no allocation.

use std::collections::HashSet;
use std::sync::Mutex;
use std::time::Instant;

use super::plan::{Algo, ExecPlan};
use super::{ArtifactMeta, Registry, RuntimeError};
use crate::ndarray::Mat;
use crate::simgpu::trace::{self, NullSink, TraceSink, TRACE_BLOCK_THREADS};
use crate::sparse::{
    CmrsPadded, CmrsSlabs, Ell, EllSlabs, GcooPadded, GcooSlabs, RowSplitPadded, RowSplitSlabs,
};

/// An operand's converted device form — what the coordinator's operand
/// store caches at registration so handle traffic executes straight from
/// slabs, skipping conversion entirely (the paper's EO, paid once per
/// registered A instead of once per request).
#[derive(Clone, Debug)]
pub enum DeviceOperand {
    /// GCOO slabs at the plan's `(g, cap)` geometry.
    Gcoo(GcooPadded),
    /// ELL slabs at the plan's `(n, rowcap)` geometry.
    Ell(Ell),
    /// CMRS strip slabs at the plan's `(g, cap)` geometry (a GcooPadded
    /// layout twin; in-slab order is the round-robin interleave).
    Cmrs(CmrsPadded),
    /// Row-split segment slabs at the plan's segment `cap` (the segment
    /// count is content-derived, carried by the padded form).
    RowSplit(RowSplitPadded),
    /// Dense A padded to the plan's execution size.
    Dense(Mat),
}

impl DeviceOperand {
    /// Bytes held by this device form (the operand store's budget unit).
    pub fn bytes(&self) -> usize {
        match self {
            DeviceOperand::Gcoo(p) => p.as_slabs().bytes(),
            DeviceOperand::Ell(e) => e.as_slabs().bytes(),
            DeviceOperand::Cmrs(p) => p.as_slabs().bytes(),
            DeviceOperand::RowSplit(p) => p.as_slabs().bytes(),
            DeviceOperand::Dense(m) => m.data.len() * 4,
        }
    }
}

/// Slab-movement accounting for one execution: bytes the engine had to
/// copy (capacity re-pads) vs. materializations it skipped by borrowing
/// the caller's slabs directly (the matching-capacity zero-copy path).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CopyStats {
    pub bytes_copied: u64,
    pub copies_avoided: u64,
}

impl CopyStats {
    pub fn add(&mut self, other: CopyStats) {
        self.bytes_copied += other.bytes_copied;
        self.copies_avoided += other.copies_avoided;
    }
}

/// Result of one executed SpDM: the product, the kernel wall time, and the
/// slab-copy accounting.
#[derive(Clone, Debug)]
pub struct SpdmOutput {
    pub c: Mat,
    pub kernel_s: f64,
    pub artifact: String,
    pub copy: CopyStats,
}

/// Execution accounting without the result matrix — returned by the
/// `_into` entry points, which write C into a caller-owned buffer.
#[derive(Clone, Debug)]
pub struct ExecStats {
    pub kernel_s: f64,
    pub artifact: String,
    pub copy: CopyStats,
}

/// Execution engine with a per-artifact compile cache. `Send + Sync` via the
/// internal mutexes; the coordinator still builds one engine per worker (the
/// per-worker device-context pattern it would need under PJRT).
pub struct Engine {
    /// Names of artifacts already loaded ("compiled").
    cache: Mutex<HashSet<String>>,
    /// compile timings per artifact (observability; tests assert caching).
    compile_log: Mutex<Vec<(String, f64)>>,
}

impl Engine {
    pub fn new() -> Result<Engine, RuntimeError> {
        Ok(Engine {
            cache: Mutex::new(HashSet::new()),
            compile_log: Mutex::new(Vec::new()),
        })
    }

    /// Backing execution platform.
    pub fn platform(&self) -> String {
        "cpu-substrate".to_string()
    }

    /// Load an artifact (cached). The artifact file must exist and be
    /// readable — a registry entry alone is not runnable.
    fn load(&self, meta: &ArtifactMeta) -> Result<(), RuntimeError> {
        if self.cache.lock().unwrap().contains(&meta.name) {
            return Ok(());
        }
        let t0 = Instant::now();
        std::fs::File::open(&meta.file).map_err(|e| {
            RuntimeError::Exec(format!("{}: {e}", meta.file.display()))
        })?;
        self.compile_log
            .lock()
            .unwrap()
            .push((meta.name.clone(), t0.elapsed().as_secs_f64()));
        self.cache.lock().unwrap().insert(meta.name.clone());
        Ok(())
    }

    /// Number of distinct artifacts compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    pub fn compile_log(&self) -> Vec<(String, f64)> {
        self.compile_log.lock().unwrap().clone()
    }

    /// Run GCOOSpDM from an owned padded form (borrows it — no copy).
    pub fn run_gcoo(
        &self,
        reg: &Registry,
        padded: &GcooPadded,
        b: &Mat,
        reuse: bool,
    ) -> Result<SpdmOutput, RuntimeError> {
        self.run_gcoo_slabs(reg, padded.as_slabs(), b, reuse)
    }

    /// Run GCOOSpDM over borrowed device slabs: pick the artifact from
    /// `reg`, run **every shape check before any slab materialization**
    /// (cheap-fail first), then execute — directly on the borrowed slabs
    /// when the artifact capacity matches (zero copies), re-padding into a
    /// local buffer only when it differs.
    pub fn run_gcoo_slabs(
        &self,
        reg: &Registry,
        slabs: GcooSlabs<'_>,
        b: &Mat,
        reuse: bool,
    ) -> Result<SpdmOutput, RuntimeError> {
        let mut c = Mat::zeros(0, 0);
        let s = self.run_gcoo_slabs_into(reg, slabs, b, reuse, &mut c)?;
        Ok(SpdmOutput { c, kernel_s: s.kernel_s, artifact: s.artifact, copy: s.copy })
    }

    /// [`Engine::run_gcoo_slabs`], writing C into a caller-owned buffer
    /// (reused across calls — the batch path's stacked-C staging). `b` may
    /// be wide: `meta.n × w·meta.n` for a fused batch of width `w`.
    pub fn run_gcoo_slabs_into(
        &self,
        reg: &Registry,
        slabs: GcooSlabs<'_>,
        b: &Mat,
        reuse: bool,
        c: &mut Mat,
    ) -> Result<ExecStats, RuntimeError> {
        self.run_gcoo_slabs_into_sink(reg, slabs, b, reuse, c, &mut NullSink)
    }

    /// [`Engine::run_gcoo_slabs_into`] under a [`TraceSink`]: computes the
    /// identical product while emitting the GCOOSpDM kernel's warp-level
    /// memory events (from the post-repad device slabs, in launch order)
    /// when the sink is active.
    pub fn run_gcoo_slabs_into_sink<S: TraceSink>(
        &self,
        reg: &Registry,
        slabs: GcooSlabs<'_>,
        b: &Mat,
        reuse: bool,
        c: &mut Mat,
        sink: &mut S,
    ) -> Result<ExecStats, RuntimeError> {
        let algo = if reuse { "gcoo" } else { "gcoo_noreuse" };
        let n = b.rows;
        let meta = reg.select(algo, n, slabs.cap)?;
        let cap = meta.param("cap").expect("gcoo artifact has cap");
        check_gcoo_slabs(&slabs)?;
        check(b.rows == meta.n && b.cols > 0 && b.cols % meta.n == 0, || {
            format!("B is {}x{}, artifact n={} (cols must be a positive multiple)", b.rows, b.cols, meta.n)
        })?;
        check(slabs.g * slabs.p == meta.n, || {
            format!("A bands {}x{} != n={}", slabs.g, slabs.p, meta.n)
        })?;
        self.load(meta)?;
        // Borrow when the artifact's cap matches; re-pad only otherwise.
        let mut copy = CopyStats::default();
        let owned;
        let (vals, rows, cols): (&[f32], &[i32], &[i32]) = if cap == slabs.cap {
            copy.copies_avoided = 1;
            (slabs.vals, slabs.rows, slabs.cols)
        } else {
            owned = slabs.repad(cap);
            // Bill bytes actually copied from the source slabs (the grown
            // tail is zero-filled, not moved) — same convention as the
            // pool's pad accounting.
            copy.bytes_copied = (slabs.g * slabs.cap.min(cap) * 12) as u64;
            (owned.vals.as_slice(), owned.rows.as_slice(), owned.cols.as_slice())
        };
        if sink.active() {
            emit_gcoo_trace(sink, vals, cols, slabs.g, cap, slabs.p, meta.n, b.cols, reuse);
        }
        let t0 = Instant::now();
        gcoo_spdm_cpu(vals, rows, cols, slabs.g, cap, slabs.p, b, c);
        let kernel_s = t0.elapsed().as_secs_f64();
        Ok(ExecStats { kernel_s, artifact: meta.name.clone(), copy })
    }

    /// Run the CSR (cuSPARSE-analog) kernel from an owned ELL (borrowed).
    pub fn run_csr(&self, reg: &Registry, ell: &Ell, b: &Mat) -> Result<SpdmOutput, RuntimeError> {
        self.run_ell_slabs(reg, ell.as_slabs(), b)
    }

    /// Run the CSR kernel over borrowed ELL slabs; same contract as
    /// [`Engine::run_gcoo_slabs`] — checks first, borrow when the row
    /// capacity matches, re-pad otherwise.
    pub fn run_ell_slabs(
        &self,
        reg: &Registry,
        slabs: EllSlabs<'_>,
        b: &Mat,
    ) -> Result<SpdmOutput, RuntimeError> {
        let mut c = Mat::zeros(0, 0);
        let s = self.run_ell_slabs_into(reg, slabs, b, &mut c)?;
        Ok(SpdmOutput { c, kernel_s: s.kernel_s, artifact: s.artifact, copy: s.copy })
    }

    /// [`Engine::run_ell_slabs`] into a caller-owned C buffer; `b` may be
    /// wide (`meta.n × w·meta.n`), like the GCOO variant.
    pub fn run_ell_slabs_into(
        &self,
        reg: &Registry,
        slabs: EllSlabs<'_>,
        b: &Mat,
        c: &mut Mat,
    ) -> Result<ExecStats, RuntimeError> {
        self.run_ell_slabs_into_sink(reg, slabs, b, c, &mut NullSink)
    }

    /// [`Engine::run_ell_slabs_into`] under a [`TraceSink`]: emits the
    /// cuSPARSE-analog kernel's scattered-load event stream (from the
    /// post-repad ELL slabs) when the sink is active.
    pub fn run_ell_slabs_into_sink<S: TraceSink>(
        &self,
        reg: &Registry,
        slabs: EllSlabs<'_>,
        b: &Mat,
        c: &mut Mat,
        sink: &mut S,
    ) -> Result<ExecStats, RuntimeError> {
        let n = b.rows;
        let meta = reg.select("csr", n, slabs.rowcap)?;
        let rowcap = meta.param("rowcap").expect("csr artifact has rowcap");
        check(
            slabs.vals.len() == slabs.n * slabs.rowcap
                && slabs.cols.len() == slabs.n * slabs.rowcap,
            || {
                format!(
                    "ell slabs: lengths {}/{} != n*rowcap {}",
                    slabs.vals.len(),
                    slabs.cols.len(),
                    slabs.n * slabs.rowcap
                )
            },
        )?;
        check(
            slabs.n == meta.n && b.rows == meta.n && b.cols > 0 && b.cols % meta.n == 0,
            || format!("shape mismatch: ell.n={} b={}x{} n={}", slabs.n, b.rows, b.cols, meta.n),
        )?;
        self.load(meta)?;
        let mut copy = CopyStats::default();
        let owned;
        let (vals, cols): (&[f32], &[i32]) = if rowcap == slabs.rowcap {
            copy.copies_avoided = 1;
            (slabs.vals, slabs.cols)
        } else {
            owned = slabs.repad(rowcap);
            copy.bytes_copied = (slabs.n * slabs.rowcap.min(rowcap) * 8) as u64;
            (owned.vals.as_slice(), owned.cols.as_slice())
        };
        if sink.active() {
            emit_ell_trace(sink, vals, cols, meta.n, rowcap, b.cols);
        }
        let t0 = Instant::now();
        ell_spdm_cpu(vals, cols, meta.n, rowcap, b, c);
        let kernel_s = t0.elapsed().as_secs_f64();
        Ok(ExecStats { kernel_s, artifact: meta.name.clone(), copy })
    }

    /// Run CMRS SpDM from an owned padded form (borrows it — no copy).
    pub fn run_cmrs(
        &self,
        reg: &Registry,
        padded: &CmrsPadded,
        b: &Mat,
    ) -> Result<SpdmOutput, RuntimeError> {
        self.run_cmrs_slabs(reg, padded.as_slabs(), b)
    }

    /// Run CMRS SpDM over borrowed strip slabs; same contract as
    /// [`Engine::run_gcoo_slabs`] — checks first, borrow when the strip
    /// capacity matches, re-pad otherwise (order-preserving, so repad
    /// never perturbs the accumulation order).
    pub fn run_cmrs_slabs(
        &self,
        reg: &Registry,
        slabs: CmrsSlabs<'_>,
        b: &Mat,
    ) -> Result<SpdmOutput, RuntimeError> {
        let mut c = Mat::zeros(0, 0);
        let s = self.run_cmrs_slabs_into(reg, slabs, b, &mut c)?;
        Ok(SpdmOutput { c, kernel_s: s.kernel_s, artifact: s.artifact, copy: s.copy })
    }

    /// [`Engine::run_cmrs_slabs`] into a caller-owned C buffer; `b` may be
    /// wide (`meta.n × w·meta.n`), like the GCOO variant.
    pub fn run_cmrs_slabs_into(
        &self,
        reg: &Registry,
        slabs: CmrsSlabs<'_>,
        b: &Mat,
        c: &mut Mat,
    ) -> Result<ExecStats, RuntimeError> {
        self.run_cmrs_slabs_into_sink(reg, slabs, b, c, &mut NullSink)
    }

    /// [`Engine::run_cmrs_slabs_into`] under a [`TraceSink`]: emits the
    /// CMRS kernel's event stream (the GCOO block walk over the
    /// round-robin interleaved entry order, where column runs — and hence
    /// B-load reuse — are naturally rare) when the sink is active.
    pub fn run_cmrs_slabs_into_sink<S: TraceSink>(
        &self,
        reg: &Registry,
        slabs: CmrsSlabs<'_>,
        b: &Mat,
        c: &mut Mat,
        sink: &mut S,
    ) -> Result<ExecStats, RuntimeError> {
        let n = b.rows;
        let meta = reg.select("cmrs", n, slabs.cap)?;
        let cap = meta.param("cap").expect("cmrs artifact has cap");
        check_cmrs_slabs(&slabs)?;
        check(b.rows == meta.n && b.cols > 0 && b.cols % meta.n == 0, || {
            format!(
                "B is {}x{}, artifact n={} (cols must be a positive multiple)",
                b.rows, b.cols, meta.n
            )
        })?;
        check(slabs.g * slabs.p == meta.n, || {
            format!("A strips {}x{} != n={}", slabs.g, slabs.p, meta.n)
        })?;
        self.load(meta)?;
        let mut copy = CopyStats::default();
        let owned;
        let (vals, rows, cols): (&[f32], &[i32], &[i32]) = if cap == slabs.cap {
            copy.copies_avoided = 1;
            (slabs.vals, slabs.rows, slabs.cols)
        } else {
            owned = slabs.repad(cap);
            copy.bytes_copied = (slabs.g * slabs.cap.min(cap) * 12) as u64;
            (owned.vals.as_slice(), owned.rows.as_slice(), owned.cols.as_slice())
        };
        if sink.active() {
            emit_cmrs_trace(sink, vals, cols, slabs.g, cap, slabs.p, meta.n, b.cols);
        }
        let t0 = Instant::now();
        cmrs_spdm_cpu(vals, rows, cols, slabs.g, cap, slabs.p, b, c);
        let kernel_s = t0.elapsed().as_secs_f64();
        Ok(ExecStats { kernel_s, artifact: meta.name.clone(), copy })
    }

    /// Run row-split SpDM from an owned padded form (borrows it — no copy).
    pub fn run_rowsplit(
        &self,
        reg: &Registry,
        padded: &RowSplitPadded,
        b: &Mat,
    ) -> Result<SpdmOutput, RuntimeError> {
        self.run_rowsplit_slabs(reg, padded.as_slabs(), b)
    }

    /// Run row-split SpDM over borrowed segment slabs. Row-split has no
    /// capacity failure mode — any segment cap fits any matrix — so
    /// artifact selection prefers the slabs' own capacity (borrow path)
    /// and otherwise falls back to the smallest compiled capacity,
    /// re-segmenting into it (order-preserving, bitwise-safe).
    pub fn run_rowsplit_slabs(
        &self,
        reg: &Registry,
        slabs: RowSplitSlabs<'_>,
        b: &Mat,
    ) -> Result<SpdmOutput, RuntimeError> {
        let mut c = Mat::zeros(0, 0);
        let s = self.run_rowsplit_slabs_into(reg, slabs, b, &mut c)?;
        Ok(SpdmOutput { c, kernel_s: s.kernel_s, artifact: s.artifact, copy: s.copy })
    }

    /// [`Engine::run_rowsplit_slabs`] into a caller-owned C buffer; `b`
    /// may be wide (`meta.n × w·meta.n`), like the GCOO variant.
    pub fn run_rowsplit_slabs_into(
        &self,
        reg: &Registry,
        slabs: RowSplitSlabs<'_>,
        b: &Mat,
        c: &mut Mat,
    ) -> Result<ExecStats, RuntimeError> {
        self.run_rowsplit_slabs_into_sink(reg, slabs, b, c, &mut NullSink)
    }

    /// [`Engine::run_rowsplit_slabs_into`] under a [`TraceSink`]: emits
    /// the warp-per-segment kernel's event stream (contiguous A streams,
    /// per-entry broadcasts, texture-path B tiles) when the sink is
    /// active.
    pub fn run_rowsplit_slabs_into_sink<S: TraceSink>(
        &self,
        reg: &Registry,
        slabs: RowSplitSlabs<'_>,
        b: &Mat,
        c: &mut Mat,
        sink: &mut S,
    ) -> Result<ExecStats, RuntimeError> {
        let n = b.rows;
        let meta = reg
            .select("rowsplit", n, slabs.cap)
            .or_else(|_| reg.select("rowsplit", n, 1))?;
        let cap = meta.param("cap").expect("rowsplit artifact has cap");
        check_rowsplit_slabs(&slabs)?;
        check(b.rows == meta.n && b.cols > 0 && b.cols % meta.n == 0, || {
            format!(
                "B is {}x{}, artifact n={} (cols must be a positive multiple)",
                b.rows, b.cols, meta.n
            )
        })?;
        check(slabs.n == meta.n, || format!("A rows {} != n={}", slabs.n, meta.n))?;
        check(slabs.seg_rows.iter().all(|&r| (r as usize) < meta.n), || {
            format!("rowsplit segment row out of range (n={})", meta.n)
        })?;
        self.load(meta)?;
        let mut copy = CopyStats::default();
        let owned;
        let (vals, seg_rows, cols, segs): (&[f32], &[i32], &[i32], usize) = if cap == slabs.cap {
            copy.copies_avoided = 1;
            (slabs.vals, slabs.seg_rows, slabs.cols, slabs.segs)
        } else {
            owned = slabs.repad(cap);
            // Re-segmentation moves exactly the stored entries (vals +
            // cols, 8 B each); padding is written fresh, not copied.
            let nnz = slabs.vals.iter().filter(|v| **v != 0.0).count();
            copy.bytes_copied = (nnz * 8) as u64;
            (
                owned.vals.as_slice(),
                owned.seg_rows.as_slice(),
                owned.cols.as_slice(),
                owned.segs,
            )
        };
        if sink.active() {
            emit_rowsplit_trace(sink, vals, seg_rows, cols, segs, cap, b.cols);
        }
        let t0 = Instant::now();
        rowsplit_spdm_cpu(vals, seg_rows, cols, segs, cap, meta.n, b, c);
        let kernel_s = t0.elapsed().as_secs_f64();
        Ok(ExecStats { kernel_s, artifact: meta.name.clone(), copy })
    }

    /// Run the GCOO SpMV extension kernel: y = A·x (paper future work).
    pub fn run_gcoo_spmv(
        &self,
        reg: &Registry,
        padded: &GcooPadded,
        x: &[f32],
    ) -> Result<(Vec<f32>, f64, String), RuntimeError> {
        let slabs = padded.as_slabs();
        let n = x.len();
        let meta = reg.select("gcoo_spmv", n, slabs.cap)?;
        let cap = meta.param("cap").expect("spmv artifact has cap");
        check_gcoo_slabs(&slabs)?;
        check(slabs.g * slabs.p == meta.n && n == meta.n, || {
            format!("spmv shapes: A bands {}x{}, x len {}, artifact n={}", slabs.g, slabs.p, n, meta.n)
        })?;
        self.load(meta)?;
        let owned;
        let (vals, rows, cols): (&[f32], &[i32], &[i32]) = if cap == slabs.cap {
            (slabs.vals, slabs.rows, slabs.cols)
        } else {
            owned = slabs.repad(cap);
            (owned.vals.as_slice(), owned.rows.as_slice(), owned.cols.as_slice())
        };
        let t0 = Instant::now();
        let y = gcoo_spmv_cpu(vals, rows, cols, slabs.g, cap, slabs.p, x);
        let kernel_s = t0.elapsed().as_secs_f64();
        Ok((y, kernel_s, meta.name.clone()))
    }

    /// Execute a resolved plan directly from a cached [`DeviceOperand`] —
    /// the handle-path entry: no stats scan, no conversion, no padding of
    /// A; the store already holds the device form at the plan's capacity,
    /// so sparse execution takes the matching-cap borrow path. `b` may be
    /// wide (`n_exec × w·n_exec`) for a fused batch; C is written into the
    /// caller-owned buffer (the worker's stacked-C staging).
    pub fn run_operand_into(
        &self,
        reg: &Registry,
        plan: &ExecPlan,
        op: &DeviceOperand,
        b: &Mat,
        c: &mut Mat,
    ) -> Result<ExecStats, RuntimeError> {
        self.run_operand_into_sink(reg, plan, op, b, c, &mut NullSink)
    }

    /// [`Engine::run_operand_into`] under a [`TraceSink`]: dispatches to
    /// the matching instrumented entry point, so handle-path execution can
    /// be traced like the inline paths.
    pub fn run_operand_into_sink<S: TraceSink>(
        &self,
        reg: &Registry,
        plan: &ExecPlan,
        op: &DeviceOperand,
        b: &Mat,
        c: &mut Mat,
        sink: &mut S,
    ) -> Result<ExecStats, RuntimeError> {
        match (plan.algo, op) {
            (Algo::Gcoo | Algo::GcooNoreuse, DeviceOperand::Gcoo(p)) => self
                .run_gcoo_slabs_into_sink(reg, p.as_slabs(), b, plan.algo == Algo::Gcoo, c, sink),
            (Algo::Csr, DeviceOperand::Ell(e)) => {
                self.run_ell_slabs_into_sink(reg, e.as_slabs(), b, c, sink)
            }
            (Algo::Cmrs, DeviceOperand::Cmrs(p)) => {
                self.run_cmrs_slabs_into_sink(reg, p.as_slabs(), b, c, sink)
            }
            (Algo::RowSplit, DeviceOperand::RowSplit(p)) => {
                self.run_rowsplit_slabs_into_sink(reg, p.as_slabs(), b, c, sink)
            }
            (Algo::DenseXla | Algo::DensePallas, DeviceOperand::Dense(a)) => {
                let out = self.run_dense_sink(reg, plan.algo.as_str(), a, b, sink)?;
                *c = out.c;
                Ok(ExecStats { kernel_s: out.kernel_s, artifact: out.artifact, copy: out.copy })
            }
            _ => Err(RuntimeError::Shape(format!(
                "device operand family does not match plan algo {}",
                plan.algo.as_str()
            ))),
        }
    }

    /// [`Engine::run_operand_into`] returning an owned C (single-request
    /// handle path).
    pub fn run_operand(
        &self,
        reg: &Registry,
        plan: &ExecPlan,
        op: &DeviceOperand,
        b: &Mat,
    ) -> Result<SpdmOutput, RuntimeError> {
        let mut c = Mat::zeros(0, 0);
        let s = self.run_operand_into(reg, plan, op, b, &mut c)?;
        Ok(SpdmOutput { c, kernel_s: s.kernel_s, artifact: s.artifact, copy: s.copy })
    }

    /// Run a dense baseline ("dense_xla" = the vendor GEMM, "dense_pallas"
    /// = the explicit tiled kernel). `b` may be wide (`n × w·n`).
    pub fn run_dense(
        &self,
        reg: &Registry,
        algo: &str,
        a: &Mat,
        b: &Mat,
    ) -> Result<SpdmOutput, RuntimeError> {
        self.run_dense_sink(reg, algo, a, b, &mut NullSink)
    }

    /// [`Engine::run_dense`] under a [`TraceSink`]: emits the tiled-GEMM
    /// event stream for the `a.rows × a.cols × b.cols` problem when the
    /// sink is active.
    pub fn run_dense_sink<S: TraceSink>(
        &self,
        reg: &Registry,
        algo: &str,
        a: &Mat,
        b: &Mat,
        sink: &mut S,
    ) -> Result<SpdmOutput, RuntimeError> {
        let n = b.rows;
        let meta = reg.select(algo, n, 0)?;
        check(a.rows == n && a.cols == n && b.cols > 0 && b.cols % n == 0, || {
            format!("dense shapes {}x{} / {}x{}", a.rows, a.cols, b.rows, b.cols)
        })?;
        self.load(meta)?;
        if sink.active() {
            emit_gemm_trace(sink, a.rows, a.cols, b.cols);
        }
        let t0 = Instant::now();
        let c = a.matmul(b);
        let kernel_s = t0.elapsed().as_secs_f64();
        Ok(SpdmOutput { c, kernel_s, artifact: meta.name.clone(), copy: CopyStats::default() })
    }
}

fn check(ok: bool, msg: impl FnOnce() -> String) -> Result<(), RuntimeError> {
    if ok {
        Ok(())
    } else {
        Err(RuntimeError::Shape(msg()))
    }
}

/// Slab lengths must match the declared (g, cap) geometry — slab fields
/// are public, so a hand-built value can be inconsistent; reject it as a
/// shape error rather than panicking mid-kernel.
fn check_gcoo_slabs(p: &GcooSlabs<'_>) -> Result<(), RuntimeError> {
    let want = p.g * p.cap;
    check(
        p.vals.len() == want && p.rows.len() == want && p.cols.len() == want,
        || {
            format!(
                "gcoo slabs: lengths {}/{}/{} != g*cap {}",
                p.vals.len(),
                p.rows.len(),
                p.cols.len(),
                want
            )
        },
    )
}

/// CMRS slab geometry check — a [`check_gcoo_slabs`] layout twin.
fn check_cmrs_slabs(p: &CmrsSlabs<'_>) -> Result<(), RuntimeError> {
    let want = p.g * p.cap;
    check(
        p.vals.len() == want && p.rows.len() == want && p.cols.len() == want,
        || {
            format!(
                "cmrs slabs: lengths {}/{}/{} != g*cap {}",
                p.vals.len(),
                p.rows.len(),
                p.cols.len(),
                want
            )
        },
    )
}

/// Row-split slab geometry check: entry arrays span segs·cap slots and the
/// per-segment row array spans segs.
fn check_rowsplit_slabs(p: &RowSplitSlabs<'_>) -> Result<(), RuntimeError> {
    let want = p.segs * p.cap;
    check(
        p.vals.len() == want && p.cols.len() == want && p.seg_rows.len() == p.segs,
        || {
            format!(
                "rowsplit slabs: lengths {}/{}/{} != segs*cap {} / segs {}",
                p.vals.len(),
                p.cols.len(),
                p.seg_rows.len(),
                want,
                p.segs
            )
        },
    )
}

/// Emit the GCOOSpDM kernel's full-grid event stream from the post-repad
/// device slabs: g bands × ⌈m/b⌉ column tiles in launch order (band index
/// fastest), each block's stream produced by the shared
/// [`trace::emit_gcoo_block`] emitter over the band's stored (col,row)-
/// sorted entry columns (padding slots hold 0.0 and are skipped, exactly
/// as the kernel skips them). `m = b.cols` covers wide-B batches; FLOPs
/// are exact: 2 · nnz · m.
#[allow(clippy::too_many_arguments)]
fn emit_gcoo_trace<S: TraceSink>(
    sink: &mut S,
    vals: &[f32],
    cols: &[i32],
    g: usize,
    cap: usize,
    p: usize,
    n_rows: usize,
    m: usize,
    reuse: bool,
) {
    let band_cols: Vec<Vec<u32>> = (0..g)
        .map(|gi| {
            (0..cap)
                .filter(|&k| vals[gi * cap + k] != 0.0)
                .map(|k| cols[gi * cap + k] as u32)
                .collect()
        })
        .collect();
    let bt = TRACE_BLOCK_THREADS;
    let total = g * m.div_ceil(bt);
    sink.grid(total, total);
    for blk in 0..total {
        trace::emit_gcoo_block(
            sink,
            blk,
            &band_cols[blk % g],
            blk % g,
            blk / g,
            p,
            bt,
            reuse,
            n_rows,
            m,
        );
    }
    let nnz: u64 = band_cols.iter().map(|c| c.len() as u64).sum();
    sink.flops(2 * nnz * m as u64);
}

/// Emit the CMRS kernel's full-grid event stream from the post-repad strip
/// slabs: g strips × ⌈m/b⌉ column tiles in launch order (strip index
/// fastest), each block streamed through [`trace::emit_cmrs_block`] over
/// the strip's stored *interleaved* entry columns — the order difference
/// (vs. GCOO's (col,row) sort) is exactly what makes CMRS's cost profile
/// distinct: column runs, and hence B-load reuse, rarely survive the
/// round-robin interleave, but no warp stalls on one heavy row.
#[allow(clippy::too_many_arguments)]
fn emit_cmrs_trace<S: TraceSink>(
    sink: &mut S,
    vals: &[f32],
    cols: &[i32],
    g: usize,
    cap: usize,
    p: usize,
    n_rows: usize,
    m: usize,
) {
    let strip_cols: Vec<Vec<u32>> = (0..g)
        .map(|si| {
            (0..cap)
                .filter(|&k| vals[si * cap + k] != 0.0)
                .map(|k| cols[si * cap + k] as u32)
                .collect()
        })
        .collect();
    let bt = TRACE_BLOCK_THREADS;
    let total = g * m.div_ceil(bt);
    sink.grid(total, total);
    for blk in 0..total {
        trace::emit_cmrs_block(
            sink,
            blk,
            &strip_cols[blk % g],
            blk % g,
            blk / g,
            p,
            bt,
            n_rows,
            m,
        );
    }
    let nnz: u64 = strip_cols.iter().map(|c| c.len() as u64).sum();
    sink.flops(2 * nnz * m as u64);
}

/// Emit the row-split kernel's full-grid event stream from the post-repad
/// segment slabs: ⌈segs/warps⌉ segment blocks × ⌈m/b⌉ column tiles in
/// launch order (segment block fastest), each block streamed through
/// [`trace::emit_rowsplit_block`] with one warp per segment.
fn emit_rowsplit_trace<S: TraceSink>(
    sink: &mut S,
    vals: &[f32],
    seg_rows: &[i32],
    cols: &[i32],
    segs: usize,
    cap: usize,
    m: usize,
) {
    let seg_entries: Vec<(u32, Vec<u32>)> = (0..segs)
        .map(|s| {
            let entry_cols = (0..cap)
                .filter(|&k| vals[s * cap + k] != 0.0)
                .map(|k| cols[s * cap + k] as u32)
                .collect();
            (seg_rows[s] as u32, entry_cols)
        })
        .collect();
    let bt = TRACE_BLOCK_THREADS;
    let warps = bt / trace::WARP;
    let seg_blocks = segs.div_ceil(warps).max(1);
    let total = seg_blocks * m.div_ceil(bt);
    sink.grid(total, total);
    for blk in 0..total {
        let sb = blk % seg_blocks;
        let jb = blk / seg_blocks;
        let lo = (sb * warps).min(segs);
        let hi = (lo + warps).min(segs);
        trace::emit_rowsplit_block(sink, blk, &seg_entries[lo..hi], lo, cap, jb, bt, m);
    }
    let nnz: u64 = seg_entries.iter().map(|(_, c)| c.len() as u64).sum();
    sink.flops(2 * nnz * m as u64);
}

/// Emit the cuSPARSE-analog kernel's full-grid event stream from the
/// post-repad ELL slabs: ⌈n/b⌉ row blocks, each thread owning one row's
/// stored column list (padding slots skipped), streamed through the shared
/// [`trace::emit_csr_block`] emitter. The kernel's C-column loop is
/// sampled at the model's stride; the m/j_samples factor is declared via
/// `inner_sample` so recorded traces replay at the walker's exact scale.
fn emit_ell_trace<S: TraceSink>(
    sink: &mut S,
    vals: &[f32],
    cols: &[i32],
    n: usize,
    rowcap: usize,
    m: usize,
) {
    let bt = TRACE_BLOCK_THREADS;
    let total = n.div_ceil(bt);
    let j_samples = 16usize.min(m);
    let j_stride = (m / j_samples).max(1);
    sink.grid(total, total);
    sink.inner_sample(m, j_samples);
    for blk in 0..total {
        let rows: Vec<Vec<u32>> = (0..bt)
            .map(|t| {
                let r = blk * bt + t;
                if r < n {
                    (0..rowcap)
                        .filter(|&k| vals[r * rowcap + k] != 0.0)
                        .map(|k| cols[r * rowcap + k] as u32)
                        .collect()
                } else {
                    Vec::new()
                }
            })
            .collect();
        trace::emit_csr_block(sink, blk, &rows, bt, m, j_samples, j_stride);
    }
    let nnz = vals.iter().filter(|v| **v != 0.0).count() as u64;
    sink.flops(2 * nnz * m as u64);
}

/// Emit the tiled dense GEMM's full-grid event stream for an
/// `n_i × n_k · n_k × n_j` product (wide-B capable via `n_j`), one
/// [`trace::emit_gemm_block`] per 64×64 C tile in launch order.
fn emit_gemm_trace<S: TraceSink>(sink: &mut S, n_i: usize, n_k: usize, n_j: usize) {
    let tiles_i = n_i.div_ceil(trace::GEMM_TILE);
    let tiles_j = n_j.div_ceil(trace::GEMM_TILE);
    let total = tiles_i * tiles_j;
    sink.grid(total, total);
    for blk in 0..total {
        trace::emit_gemm_block(sink, blk, blk % tiles_i, blk / tiles_i, n_i, n_k, n_j);
    }
    sink.flops(2 * n_i as u64 * n_k as u64 * n_j as u64);
}

/// Reference GCOOSpDM over the padded device slabs: every stored nonzero
/// scatters its scaled B row into C (padding slots hold 0.0 and vanish).
/// Mirrors paper Algorithm 2's output indexing: C row = band·p + local row.
/// C spans `b.cols` columns, so a stacked wide B yields the wide C whose
/// `n`-column blocks are exactly the per-request products (each output
/// column accumulates the same ordered f32 sum as a width-1 run — the
/// bitwise identity the differential suite asserts).
fn gcoo_spdm_cpu(
    vals: &[f32],
    rows: &[i32],
    cols: &[i32],
    g: usize,
    cap: usize,
    p: usize,
    b: &Mat,
    c: &mut Mat,
) {
    c.zero_into(g * p, b.cols);
    for gi in 0..g {
        for k in 0..cap {
            let v = vals[gi * cap + k];
            if v == 0.0 {
                continue;
            }
            let row = gi * p + rows[gi * cap + k] as usize;
            let brow = b.row(cols[gi * cap + k] as usize);
            let crow = c.row_mut(row);
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += v * bv;
            }
        }
    }
}

/// Reference GCOO SpMV over the same slabs: y[band·p + row] += v · x[col].
fn gcoo_spmv_cpu(
    vals: &[f32],
    rows: &[i32],
    cols: &[i32],
    g: usize,
    cap: usize,
    p: usize,
    x: &[f32],
) -> Vec<f32> {
    let mut y = vec![0.0f32; g * p];
    for gi in 0..g {
        for k in 0..cap {
            let v = vals[gi * cap + k];
            if v == 0.0 {
                continue;
            }
            y[gi * p + rows[gi * cap + k] as usize] += v * x[cols[gi * cap + k] as usize];
        }
    }
    y
}

/// Reference ELL (padded CSR) SpDM; wide-B capable like the GCOO kernel.
fn ell_spdm_cpu(vals: &[f32], cols: &[i32], n: usize, rowcap: usize, b: &Mat, c: &mut Mat) {
    c.zero_into(n, b.cols);
    for i in 0..n {
        for k in 0..rowcap {
            let v = vals[i * rowcap + k];
            if v == 0.0 {
                continue;
            }
            let brow = b.row(cols[i * rowcap + k] as usize);
            let crow = c.row_mut(i);
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += v * bv;
            }
        }
    }
}

/// Reference CMRS SpDM. The padded slab layout is a GcooPadded twin
/// (g strips × cap slots, strip-local rows), so the scatter loop is shared
/// verbatim; only the in-slab entry *order* differs (round-robin
/// interleave). Each C row still receives its entries in ascending column
/// order — the interleave preserves per-row order — so every output
/// element accumulates the identical ordered f32 sum as the GCOO/dense
/// reference (the bitwise identity the family differential asserts).
#[allow(clippy::too_many_arguments)]
fn cmrs_spdm_cpu(
    vals: &[f32],
    rows: &[i32],
    cols: &[i32],
    g: usize,
    cap: usize,
    p: usize,
    b: &Mat,
    c: &mut Mat,
) {
    gcoo_spdm_cpu(vals, rows, cols, g, cap, p, b, c);
}

/// Reference row-split SpDM: segments stream in row order, each scattering
/// its scaled B rows into the owning row of C. A row's segments are
/// contiguous and its entries ascend by column across them, so every
/// output element accumulates over ascending k — bitwise identical to the
/// other families. Wide-B capable like the GCOO kernel.
fn rowsplit_spdm_cpu(
    vals: &[f32],
    seg_rows: &[i32],
    cols: &[i32],
    segs: usize,
    cap: usize,
    n: usize,
    b: &Mat,
    c: &mut Mat,
) {
    c.zero_into(n, b.cols);
    for s in 0..segs {
        let row = seg_rows[s] as usize;
        for k in 0..cap {
            let v = vals[s * cap + k];
            if v == 0.0 {
                continue;
            }
            let brow = b.row(cols[s * cap + k] as usize);
            let crow = c.row_mut(row);
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += v * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Rng;
    use crate::sparse::{Cmrs, Csr, Gcoo, RowSplit};
    use std::path::PathBuf;

    // Slab re-pad unit tests live next to the format (sparse/gcoo.rs);
    // borrowed-vs-cloned execution equivalence and the zero-copy counter
    // assertions live in rust/tests/zero_copy.rs.

    #[test]
    fn gcoo_cpu_kernel_matches_oracle() {
        let mut rng = Rng::new(41);
        let a = gen::uniform(64, 0.95, &mut rng);
        let b = Mat::randn(64, 48, &mut rng);
        let gcoo = Gcoo::from_dense(&a, 8);
        let padded = gcoo.pad(gcoo.max_group_nnz().max(1)).unwrap();
        let mut c = Mat::zeros(0, 0);
        gcoo_spdm_cpu(
            &padded.vals,
            &padded.rows,
            &padded.cols,
            padded.g,
            padded.cap,
            padded.p,
            &b,
            &mut c,
        );
        assert!(c.allclose(&a.matmul(&b), 1e-4, 1e-4));
        // The output buffer is caller-owned: a second run at the same
        // geometry reuses the allocation (the stacked-C staging contract).
        let ptr = c.data.as_ptr();
        gcoo_spdm_cpu(
            &padded.vals,
            &padded.rows,
            &padded.cols,
            padded.g,
            padded.cap,
            padded.p,
            &b,
            &mut c,
        );
        assert_eq!(c.data.as_ptr(), ptr, "steady-state kernel output reallocated");
    }

    #[test]
    fn gcoo_cpu_kernel_wide_b_blocks_match_narrow_runs() {
        // Wide B = [B1 B2]: each n-column block of the wide C must be
        // bitwise identical to the width-1 product with that B.
        let mut rng = Rng::new(47);
        let a = gen::uniform(32, 0.9, &mut rng);
        let b1 = Mat::randn(32, 32, &mut rng);
        let b2 = Mat::randn(32, 32, &mut rng);
        let mut wide = Mat::zeros(32, 64);
        for i in 0..32 {
            wide.row_mut(i)[..32].copy_from_slice(b1.row(i));
            wide.row_mut(i)[32..].copy_from_slice(b2.row(i));
        }
        let gcoo = Gcoo::from_dense(&a, 8);
        let padded = gcoo.pad(gcoo.max_group_nnz().max(1)).unwrap();
        let run = |b: &Mat| {
            let mut c = Mat::zeros(0, 0);
            gcoo_spdm_cpu(
                &padded.vals, &padded.rows, &padded.cols, padded.g, padded.cap, padded.p, b,
                &mut c,
            );
            c
        };
        let (cw, c1, c2) = (run(&wide), run(&b1), run(&b2));
        for i in 0..32 {
            assert_eq!(&cw.row(i)[..32], c1.row(i), "row {i} block 1");
            assert_eq!(&cw.row(i)[32..], c2.row(i), "row {i} block 2");
        }
    }

    #[test]
    fn spmv_cpu_kernel_matches_oracle() {
        let mut rng = Rng::new(45);
        let a = gen::uniform(48, 0.9, &mut rng);
        let x: Vec<f32> = (0..48).map(|_| rng.normal() as f32).collect();
        let gcoo = Gcoo::from_dense(&a, 8);
        let padded = gcoo.pad(gcoo.max_group_nnz().max(1)).unwrap();
        let y = gcoo_spmv_cpu(
            &padded.vals,
            &padded.rows,
            &padded.cols,
            padded.g,
            padded.cap,
            padded.p,
            &x,
        );
        let oracle = a.matmul(&Mat::from_vec(48, 1, x));
        assert_eq!(y.len(), 48);
        for (i, (got, want)) in y.iter().zip(&oracle.data).enumerate() {
            assert!((got - want).abs() < 1e-4, "y[{i}]: {got} vs {want}");
        }
    }

    #[test]
    fn ell_cpu_kernel_matches_oracle() {
        let mut rng = Rng::new(43);
        let a = gen::uniform(48, 0.9, &mut rng);
        let b = Mat::randn(48, 48, &mut rng);
        let csr = Csr::from_dense(&a);
        let ell = Ell::from_csr(&csr, csr.max_row_nnz().max(1)).unwrap();
        let mut c = Mat::zeros(0, 0);
        ell_spdm_cpu(&ell.vals, &ell.cols, ell.n, ell.rowcap, &b, &mut c);
        assert!(c.allclose(&a.matmul(&b), 1e-4, 1e-4));
    }

    /// Tentpole bitwise discipline: the CMRS kernel's output must be
    /// *bit-identical* to the GCOO kernel's (and the dense oracle's
    /// neighborhood) — the interleave reorders the stream but never any
    /// single row's accumulation order.
    #[test]
    fn cmrs_cpu_kernel_bitwise_matches_gcoo() {
        let mut rng = Rng::new(61);
        let a = gen::power_law_rows(64, 0.92, &mut rng);
        let b = Mat::randn(64, 48, &mut rng);
        let cmrs = Cmrs::from_dense(&a, 8);
        let cp = cmrs.pad(cmrs.max_strip_nnz().max(1)).unwrap();
        let mut c_cmrs = Mat::zeros(0, 0);
        cmrs_spdm_cpu(&cp.vals, &cp.rows, &cp.cols, cp.g, cp.cap, cp.p, &b, &mut c_cmrs);
        let gcoo = Gcoo::from_dense(&a, 8);
        let gp = gcoo.pad(gcoo.max_group_nnz().max(1)).unwrap();
        let mut c_gcoo = Mat::zeros(0, 0);
        gcoo_spdm_cpu(&gp.vals, &gp.rows, &gp.cols, gp.g, gp.cap, gp.p, &b, &mut c_gcoo);
        assert_eq!(c_cmrs.data, c_gcoo.data, "CMRS must be bitwise identical to GCOO");
        assert!(c_cmrs.allclose(&a.matmul(&b), 1e-4, 1e-4));
    }

    /// Same discipline for row-split, across segment capacities: cutting a
    /// row into segments never reorders its entries, so every capacity
    /// yields the same bits.
    #[test]
    fn rowsplit_cpu_kernel_bitwise_matches_gcoo_across_caps() {
        let mut rng = Rng::new(62);
        let a = gen::power_law_rows(64, 0.92, &mut rng);
        let b = Mat::randn(64, 64, &mut rng);
        let gcoo = Gcoo::from_dense(&a, 8);
        let gp = gcoo.pad(gcoo.max_group_nnz().max(1)).unwrap();
        let mut c_gcoo = Mat::zeros(0, 0);
        gcoo_spdm_cpu(&gp.vals, &gp.rows, &gp.cols, gp.g, gp.cap, gp.p, &b, &mut c_gcoo);
        for cap in [1, 3, 16, 64] {
            let rp = RowSplit::from_dense(&a, cap).unwrap().pad();
            let mut c_rs = Mat::zeros(0, 0);
            rowsplit_spdm_cpu(&rp.vals, &rp.seg_rows, &rp.cols, rp.segs, rp.cap, rp.n, &b, &mut c_rs);
            assert_eq!(c_rs.data, c_gcoo.data, "row-split cap {cap} not bitwise identical");
        }
    }

    /// Registry whose one gcoo artifact (n=16, cap=16) has no backing file.
    fn missing_file_registry() -> Registry {
        let manifest = r#"{"artifacts": [
            {"name": "gcoo_n16_cap16", "algo": "gcoo", "n": 16,
             "params": {"p": 8, "cap": 16}, "inputs": [],
             "file": "definitely_missing.hlo.txt"}
        ]}"#;
        Registry::from_manifest_json(manifest, PathBuf::from("/nonexistent-artifacts-dir"))
            .unwrap()
    }

    #[test]
    fn engine_errors_on_missing_artifact_file() {
        // Registry entries without backing files must fail to load, exactly
        // like the PJRT engine would.
        let reg = missing_file_registry();
        let engine = Engine::new().unwrap();
        let mut rng = Rng::new(44);
        let a = Mat::eye(16); // 8 nnz per band: fits the cap=16 artifact
        let b = Mat::randn(16, 16, &mut rng);
        let gcoo = Gcoo::from_dense(&a, 8);
        let padded = gcoo.pad(16).unwrap();
        let err = engine.run_gcoo(&reg, &padded, &b, true);
        assert!(matches!(err, Err(RuntimeError::Exec(_))), "{err:?}");
        assert_eq!(engine.compiled_count(), 0);
    }

    #[test]
    fn inconsistent_padded_slabs_rejected_as_shape_error() {
        // GcooPadded fields are public; a hand-built value with short slabs
        // must come back as a Shape error, not a panic.
        let reg = missing_file_registry();
        let engine = Engine::new().unwrap();
        let mut rng = Rng::new(46);
        let b = Mat::randn(16, 16, &mut rng);
        let padded = GcooPadded {
            g: 2,
            cap: 16,
            p: 8,
            n: 16,
            vals: vec![1.0; 3], // short: should be g*cap = 32
            rows: vec![0; 32],
            cols: vec![0; 32],
        };
        let err = engine.run_gcoo(&reg, &padded, &b, true);
        assert!(matches!(err, Err(RuntimeError::Shape(_))), "{err:?}");
    }

    /// `run_operand` executes a cached device form at the plan's capacity
    /// (borrow path, no repad) and rejects a plan/operand family mismatch
    /// as a shape error rather than running the wrong kernel.
    #[test]
    fn run_operand_dispatches_and_rejects_family_mismatch() {
        let dir = std::path::PathBuf::from("target/engine_operand_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("stub.hlo.txt"), b"stub").unwrap();
        let manifest = r#"{"artifacts": [
            {"name": "gcoo_n16_cap16", "algo": "gcoo", "n": 16,
             "params": {"p": 8, "cap": 16}, "inputs": [], "file": "stub.hlo.txt"},
            {"name": "dense_xla_n16", "algo": "dense_xla", "n": 16,
             "params": {}, "inputs": [], "file": "stub.hlo.txt"}
        ]}"#;
        let reg = Registry::from_manifest_json(manifest, dir).unwrap();
        let engine = Engine::new().unwrap();
        let mut rng = Rng::new(49);
        let a = Mat::eye(16); // 8 nnz per band: fits the cap=16 artifact
        let b = Mat::randn(16, 16, &mut rng);
        let gcoo = Gcoo::from_dense(&a, 8);
        let plan = ExecPlan {
            algo: Algo::Gcoo,
            n_exec: 16,
            cap: 16,
            artifact: "gcoo_n16_cap16".into(),
            reason: "test",
            width: 1,
        };
        let op = DeviceOperand::Gcoo(gcoo.pad(16).unwrap());
        assert_eq!(op.bytes(), 2 * 16 * 12, "g·cap slabs at 12 B/slot");
        let out = engine.run_operand(&reg, &plan, &op, &b).unwrap();
        assert!(out.c.allclose(&a.matmul(&b), 1e-4, 1e-4));
        assert_eq!(out.copy.copies_avoided, 1, "cached slabs at plan cap must borrow");
        assert_eq!(out.copy.bytes_copied, 0);
        // Plan/operand family mismatch → shape error, nothing executed.
        let dense_plan = ExecPlan {
            algo: Algo::DenseXla,
            n_exec: 16,
            cap: 0,
            artifact: "dense_xla_n16".into(),
            reason: "test",
            width: 1,
        };
        let err = engine.run_operand(&reg, &dense_plan, &op, &b);
        assert!(matches!(err, Err(RuntimeError::Shape(_))), "{err:?}");
        // Dense operand runs the GEMM path, moving C into the caller buffer.
        let dop = DeviceOperand::Dense(a.clone());
        let out = engine.run_operand(&reg, &dense_plan, &dop, &b).unwrap();
        assert!(out.c.allclose(&a.matmul(&b), 1e-4, 1e-4));
    }

    /// Handle-path dispatch for the new families: cached CMRS/row-split
    /// device forms at the plan's capacity execute on the borrow path and
    /// cross-family mismatches stay shape errors.
    #[test]
    fn run_operand_dispatches_cmrs_and_rowsplit() {
        let dir = std::path::PathBuf::from("target/engine_family_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("stub.hlo.txt"), b"stub").unwrap();
        let manifest = r#"{"artifacts": [
            {"name": "cmrs_n16_cap32", "algo": "cmrs", "n": 16,
             "params": {"p": 8, "cap": 32}, "inputs": [], "file": "stub.hlo.txt"},
            {"name": "rowsplit_n16_cap4", "algo": "rowsplit", "n": 16,
             "params": {"cap": 4}, "inputs": [], "file": "stub.hlo.txt"}
        ]}"#;
        let reg = Registry::from_manifest_json(manifest, dir).unwrap();
        let engine = Engine::new().unwrap();
        let mut rng = Rng::new(63);
        let a = gen::uniform(16, 0.9, &mut rng);
        let b = Mat::randn(16, 16, &mut rng);
        let oracle = a.matmul(&b);

        let cmrs = Cmrs::from_dense(&a, 8);
        let plan = ExecPlan {
            algo: Algo::Cmrs,
            n_exec: 16,
            cap: 32,
            artifact: "cmrs_n16_cap32".into(),
            reason: "test",
            width: 1,
        };
        let op = DeviceOperand::Cmrs(cmrs.pad(32).unwrap());
        assert_eq!(op.bytes(), 2 * 32 * 12, "g·cap strip slabs at 12 B/slot");
        let out = engine.run_operand(&reg, &plan, &op, &b).unwrap();
        assert!(out.c.allclose(&oracle, 1e-4, 1e-4));
        assert_eq!(out.copy.copies_avoided, 1, "cached strips at plan cap must borrow");

        let rs = RowSplit::from_dense(&a, 4).unwrap().pad();
        let rs_plan = ExecPlan {
            algo: Algo::RowSplit,
            n_exec: 16,
            cap: 4,
            artifact: "rowsplit_n16_cap4".into(),
            reason: "test",
            width: 1,
        };
        let segs = rs.segs;
        let rop = DeviceOperand::RowSplit(rs);
        assert_eq!(rop.bytes(), segs * 4 * 8 + segs * 4);
        let out = engine.run_operand(&reg, &rs_plan, &rop, &b).unwrap();
        assert!(out.c.allclose(&oracle, 1e-4, 1e-4));
        assert_eq!(out.copy.copies_avoided, 1, "cached segments at plan cap must borrow");

        // Cross-family mismatch is a shape error, nothing executed.
        let err = engine.run_operand(&reg, &plan, &rop, &b);
        assert!(matches!(err, Err(RuntimeError::Shape(_))), "{err:?}");
    }

    /// Instrumented execution emits the same trace the simgpu walker
    /// records for the same problem — the kernel↔model unification in
    /// miniature (the corpus-wide sweep lives in
    /// rust/tests/trace_differential.rs).
    #[test]
    fn traced_execution_matches_recorded_walker_traces() {
        use crate::simgpu::{record_gcoo, record_gemm, GcooStructure, TraceRecorder, WalkConfig};
        let dir = std::path::PathBuf::from("target/engine_trace_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("stub.hlo.txt"), b"stub").unwrap();
        let manifest = r#"{"artifacts": [
            {"name": "gcoo_n16_cap16", "algo": "gcoo", "n": 16,
             "params": {"p": 8, "cap": 16}, "inputs": [], "file": "stub.hlo.txt"},
            {"name": "dense_xla_n16", "algo": "dense_xla", "n": 16,
             "params": {}, "inputs": [], "file": "stub.hlo.txt"}
        ]}"#;
        let reg = Registry::from_manifest_json(manifest, dir).unwrap();
        let engine = Engine::new().unwrap();
        let mut rng = Rng::new(51);
        let a = Mat::eye(16);
        let b = Mat::randn(16, 16, &mut rng);
        let cfg = WalkConfig::default(); // window covers the whole 16-size grid

        let gcoo = Gcoo::from_dense(&a, 8);
        let padded = gcoo.pad(16).unwrap();
        let mut rec = TraceRecorder::new();
        let mut c = Mat::zeros(0, 0);
        engine
            .run_gcoo_slabs_into_sink(&reg, padded.as_slabs(), &b, true, &mut c, &mut rec)
            .unwrap();
        assert!(c.allclose(&a.matmul(&b), 1e-4, 1e-4), "tracing must not perturb the product");
        let walker = record_gcoo(&GcooStructure::new(&gcoo), &cfg, true);
        assert_eq!(rec.finish(), walker, "engine gcoo trace != walker trace");

        let mut rec = TraceRecorder::new();
        engine.run_dense_sink(&reg, "dense_xla", &a, &b, &mut rec).unwrap();
        assert_eq!(rec.finish(), record_gemm(16, &cfg), "engine dense trace != walker trace");
    }

    /// The new families' instrumented kernels emit the exact traces their
    /// walkers record — the same kernel↔model unification the GCOO/dense
    /// paths pin above.
    #[test]
    fn traced_family_execution_matches_recorded_walker_traces() {
        use crate::simgpu::{record_cmrs, record_rowsplit, GcooStructure, TraceRecorder, WalkConfig};
        let dir = std::path::PathBuf::from("target/engine_family_trace_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("stub.hlo.txt"), b"stub").unwrap();
        let manifest = r#"{"artifacts": [
            {"name": "cmrs_n16_cap32", "algo": "cmrs", "n": 16,
             "params": {"p": 8, "cap": 32}, "inputs": [], "file": "stub.hlo.txt"},
            {"name": "rowsplit_n16_cap4", "algo": "rowsplit", "n": 16,
             "params": {"cap": 4}, "inputs": [], "file": "stub.hlo.txt"}
        ]}"#;
        let reg = Registry::from_manifest_json(manifest, dir).unwrap();
        let engine = Engine::new().unwrap();
        let mut rng = Rng::new(53);
        let a = gen::uniform(16, 0.85, &mut rng);
        let b = Mat::randn(16, 16, &mut rng);
        let cfg = WalkConfig::default(); // window covers the whole 16-size grid
        let st = GcooStructure::new(&Gcoo::from_dense(&a, 8));

        let cmrs = Cmrs::from_dense(&a, 8);
        let padded = cmrs.pad(32).unwrap();
        let mut rec = TraceRecorder::new();
        let mut c = Mat::zeros(0, 0);
        engine
            .run_cmrs_slabs_into_sink(&reg, padded.as_slabs(), &b, &mut c, &mut rec)
            .unwrap();
        assert!(c.allclose(&a.matmul(&b), 1e-4, 1e-4), "tracing must not perturb the product");
        assert_eq!(rec.finish(), record_cmrs(&st, &cfg), "engine cmrs trace != walker trace");

        let rs = RowSplit::from_dense(&a, 4).unwrap().pad();
        let mut rec = TraceRecorder::new();
        engine
            .run_rowsplit_slabs_into_sink(&reg, rs.as_slabs(), &b, &mut c, &mut rec)
            .unwrap();
        assert!(c.allclose(&a.matmul(&b), 1e-4, 1e-4));
        assert_eq!(
            rec.finish(),
            record_rowsplit(&st, 4, &cfg),
            "engine rowsplit trace != walker trace"
        );
    }

    // Engine runs against a real artifacts directory live in
    // rust/tests/runtime_integration.rs (they require `make artifacts`).
}
