//! Execution planning — the algorithm families the stack routes to and the
//! per-request [`ExecPlan`] resolved **once, before any conversion**.
//!
//! The plan pins (algo, artifact, n_exec, cap) up front from the fused
//! stats scan, so the request pipeline converts A exactly once, directly
//! into device slabs of the chosen artifact's capacity. This kills the old
//! guess-then-reconvert double path (convert at a guessed size, plan, then
//! possibly convert again) and makes the engine's matching-cap check always
//! succeed on the serving path — a true zero-copy borrow.

use super::{Registry, RuntimeError};

/// Algorithm families the coordinator can route to (== artifact `algo`s).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    Gcoo,
    GcooNoreuse,
    Csr,
    DenseXla,
    DensePallas,
    /// CMRS strips (Koza et al., arXiv:1203.2946) — high-variance rows.
    Cmrs,
    /// Row-split nnz segments (Yang, Buluç & Owens, arXiv:1803.08601) —
    /// power-law rows where banded GCOO degrades.
    RowSplit,
}

impl Algo {
    pub fn as_str(&self) -> &'static str {
        match self {
            Algo::Gcoo => "gcoo",
            Algo::GcooNoreuse => "gcoo_noreuse",
            Algo::Csr => "csr",
            Algo::DenseXla => "dense_xla",
            Algo::DensePallas => "dense_pallas",
            Algo::Cmrs => "cmrs",
            Algo::RowSplit => "rowsplit",
        }
    }

    pub fn from_str(s: &str) -> Option<Algo> {
        match s {
            "gcoo" => Some(Algo::Gcoo),
            "gcoo_noreuse" => Some(Algo::GcooNoreuse),
            "csr" => Some(Algo::Csr),
            "dense_xla" | "dense" => Some(Algo::DenseXla),
            "dense_pallas" => Some(Algo::DensePallas),
            "cmrs" => Some(Algo::Cmrs),
            "rowsplit" => Some(Algo::RowSplit),
            _ => None,
        }
    }

    /// Whether this family consumes a sparse device form of A.
    pub fn is_sparse(&self) -> bool {
        matches!(
            self,
            Algo::Gcoo | Algo::GcooNoreuse | Algo::Csr | Algo::Cmrs | Algo::RowSplit
        )
    }
}

/// One request's resolved execution plan: algorithm, padded execution size,
/// the concrete artifact that will run it, and that artifact's device slab
/// capacity (band cap for GCOO, row cap for CSR/ELL, 0 for dense).
#[derive(Clone, Debug, PartialEq)]
pub struct ExecPlan {
    pub algo: Algo,
    /// Exported size the request will be padded to.
    pub n_exec: usize,
    /// Device slab capacity of the chosen artifact (0 for dense).
    pub cap: usize,
    /// Name of the artifact the engine will select for this plan.
    pub artifact: String,
    /// Why this algorithm won (observability / tests). The static
    /// selector's reasons name the paper prior ("sparse-crossover", …);
    /// adaptive routing adds "candidate" (a ranked alternative),
    /// "measured" (a gated estimate outranked the prior), "explore" (a
    /// seeded exploration draw), and "measured-flip" (a republished
    /// entry's new incumbent).
    pub reason: &'static str,
    /// Number of requests this plan executes fused (shape-affine batch):
    /// B operands are stacked column-wise into one `n_exec × width·n_exec`
    /// operand and A is converted once. The selector resolves plans at
    /// width 1; the batch path widens before execution.
    pub width: usize,
}

impl ExecPlan {
    /// Resolve the concrete artifact for `(algo, n_exec, needed_cap)` and
    /// pin its capacity into the plan. Because `Registry::select` picks the
    /// smallest capacity ≥ `needed_cap` — the same query the engine issues —
    /// converting straight to `cap` guarantees the engine re-selects this
    /// exact artifact and takes the borrow (no-repad) path.
    pub fn resolve(
        reg: &Registry,
        algo: Algo,
        n_exec: usize,
        needed_cap: usize,
        reason: &'static str,
    ) -> Result<ExecPlan, RuntimeError> {
        let meta = reg.select(algo.as_str(), n_exec, needed_cap)?;
        Ok(ExecPlan {
            algo,
            n_exec,
            cap: meta.capacity().unwrap_or(0),
            artifact: meta.name.clone(),
            reason,
            width: 1,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn reg() -> Registry {
        let manifest = r#"{"artifacts": [
            {"name": "gcoo_n256_cap64", "algo": "gcoo", "n": 256,
             "params": {"p": 8, "cap": 64}, "inputs": [], "file": "a.hlo.txt"},
            {"name": "gcoo_n256_cap512", "algo": "gcoo", "n": 256,
             "params": {"p": 8, "cap": 512}, "inputs": [], "file": "b.hlo.txt"},
            {"name": "dense_xla_n256", "algo": "dense_xla", "n": 256,
             "params": {}, "inputs": [], "file": "c.hlo.txt"}
        ]}"#;
        Registry::from_manifest_json(manifest, PathBuf::from("/nope")).unwrap()
    }

    #[test]
    fn algo_round_trip() {
        for a in [
            Algo::Gcoo,
            Algo::GcooNoreuse,
            Algo::Csr,
            Algo::DenseXla,
            Algo::DensePallas,
            Algo::Cmrs,
            Algo::RowSplit,
        ] {
            assert_eq!(Algo::from_str(a.as_str()), Some(a));
        }
        assert_eq!(Algo::from_str("dense"), Some(Algo::DenseXla));
        assert_eq!(Algo::from_str("bogus"), None);
        assert!(Algo::Gcoo.is_sparse());
        assert!(Algo::Csr.is_sparse());
        assert!(Algo::Cmrs.is_sparse());
        assert!(Algo::RowSplit.is_sparse());
        assert!(!Algo::DenseXla.is_sparse());
    }

    #[test]
    fn resolve_pins_smallest_fitting_capacity() {
        let r = reg();
        let plan = ExecPlan::resolve(&r, Algo::Gcoo, 256, 50, "test").unwrap();
        assert_eq!(plan.cap, 64);
        assert_eq!(plan.artifact, "gcoo_n256_cap64");
        assert_eq!(plan.width, 1, "plans resolve at width 1; the batcher widens");
        let plan = ExecPlan::resolve(&r, Algo::Gcoo, 256, 65, "test").unwrap();
        assert_eq!(plan.cap, 512);
    }

    #[test]
    fn resolve_dense_has_zero_cap() {
        let plan = ExecPlan::resolve(&reg(), Algo::DenseXla, 256, 0, "test").unwrap();
        assert_eq!(plan.cap, 0);
        assert_eq!(plan.artifact, "dense_xla_n256");
    }

    #[test]
    fn resolve_errors_when_capacity_exhausted() {
        assert!(ExecPlan::resolve(&reg(), Algo::Gcoo, 256, 1000, "test").is_err());
    }
}
