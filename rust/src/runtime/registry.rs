//! Artifact registry: parses `artifacts/manifest.json` (written by
//! `python -m compile.aot`) and answers variant-selection queries — e.g.
//! "the smallest-capacity gcoo executable for n=512 that fits 1300 nonzeros
//! per band". Capacity routing is a real scheduling decision: smaller caps
//! run fewer scan iterations, so picking the tightest fit is a performance
//! lever (see EXPERIMENTS.md §Perf).

use std::path::{Path, PathBuf};

use super::RuntimeError;
use crate::json;

/// One input tensor of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct InputSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// One AOT-compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub algo: String,
    pub n: usize,
    /// Kernel parameters (p, tb, cap / rp, rowcap / tm…).
    pub params: Vec<(String, usize)>,
    pub inputs: Vec<InputSpec>,
    pub file: PathBuf,
}

impl ArtifactMeta {
    pub fn param(&self, key: &str) -> Option<usize> {
        self.params.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    /// GCOO band capacity or ELL row capacity, when applicable.
    pub fn capacity(&self) -> Option<usize> {
        self.param("cap").or_else(|| self.param("rowcap"))
    }
}

/// Parsed manifest + lookup indexes.
pub struct Registry {
    pub artifacts: Vec<ArtifactMeta>,
    pub dir: PathBuf,
}

impl Registry {
    /// Load from an artifacts directory containing `manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Registry, RuntimeError> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            RuntimeError::Manifest(format!("{}: {e}", manifest_path.display()))
        })?;
        Self::from_manifest_json(&text, dir)
    }

    /// Parse manifest text (separated out for tests).
    pub fn from_manifest_json(text: &str, dir: PathBuf) -> Result<Registry, RuntimeError> {
        let root = json::parse(text).map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        let arts = root
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| RuntimeError::Manifest("missing 'artifacts' array".into()))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let get_str = |k: &str| -> Result<String, RuntimeError> {
                a.get(k)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| RuntimeError::Manifest(format!("artifact missing '{k}'")))
            };
            let name = get_str("name")?;
            let algo = get_str("algo")?;
            let n = a
                .get("n")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| RuntimeError::Manifest(format!("{name}: missing n")))?;
            let mut params = Vec::new();
            if let Some(json::Value::Obj(kvs)) = a.get("params") {
                for (k, v) in kvs {
                    if let Some(x) = v.as_usize() {
                        params.push((k.clone(), x));
                    }
                }
            }
            let mut inputs = Vec::new();
            if let Some(arr) = a.get("inputs").and_then(|v| v.as_arr()) {
                for inp in arr {
                    inputs.push(InputSpec {
                        name: inp
                            .get("name")
                            .and_then(|v| v.as_str())
                            .unwrap_or_default()
                            .to_string(),
                        dtype: inp
                            .get("dtype")
                            .and_then(|v| v.as_str())
                            .unwrap_or("float32")
                            .to_string(),
                        shape: inp
                            .get("shape")
                            .and_then(|v| v.as_arr())
                            .map(|xs| xs.iter().filter_map(|x| x.as_usize()).collect())
                            .unwrap_or_default(),
                    });
                }
            }
            let file = dir.join(get_str("file")?);
            artifacts.push(ArtifactMeta { name, algo, n, params, inputs, file });
        }
        Ok(Registry { artifacts, dir })
    }

    /// All variants of an algorithm at dimension n.
    pub fn variants(&self, algo: &str, n: usize) -> Vec<&ArtifactMeta> {
        self.artifacts.iter().filter(|a| a.algo == algo && a.n == n).collect()
    }

    /// Smallest-capacity variant that fits `needed_cap` (gcoo/csr), or the
    /// unique variant for dense algorithms.
    pub fn select(
        &self,
        algo: &str,
        n: usize,
        needed_cap: usize,
    ) -> Result<&ArtifactMeta, RuntimeError> {
        self.variants(algo, n)
            .into_iter()
            .filter(|a| a.capacity().map_or(true, |c| c >= needed_cap))
            .min_by_key(|a| a.capacity().unwrap_or(0))
            .ok_or(RuntimeError::NoVariant { algo: algo.to_string(), n, needed_cap })
    }

    /// Dimensions for which `algo` has at least one artifact, sorted.
    pub fn sizes(&self, algo: &str) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.algo == algo)
            .map(|a| a.n)
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Smallest exported n that is >= the requested dimension (requests are
    /// zero-padded up to it by the coordinator).
    pub fn fit_size(&self, algo: &str, n: usize) -> Option<usize> {
        self.sizes(algo).into_iter().find(|&s| s >= n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "schema": 1,
      "artifacts": [
        {"name": "gcoo_n256_cap64", "algo": "gcoo", "n": 256,
         "params": {"p": 8, "tb": 128, "cap": 64},
         "inputs": [{"name": "values", "dtype": "float32", "shape": [32, 64]}],
         "file": "gcoo_n256_cap64.hlo.txt"},
        {"name": "gcoo_n256_cap256", "algo": "gcoo", "n": 256,
         "params": {"p": 8, "tb": 128, "cap": 256},
         "inputs": [], "file": "gcoo_n256_cap256.hlo.txt"},
        {"name": "dense_xla_n256", "algo": "dense_xla", "n": 256,
         "params": {}, "inputs": [], "file": "dense_xla_n256.hlo.txt"},
        {"name": "gcoo_n512_cap128", "algo": "gcoo", "n": 512,
         "params": {"p": 8, "tb": 128, "cap": 128},
         "inputs": [], "file": "gcoo_n512_cap128.hlo.txt"}
      ]
    }"#;

    fn reg() -> Registry {
        Registry::from_manifest_json(SAMPLE, PathBuf::from("/tmp/arts")).unwrap()
    }

    #[test]
    fn parses_artifacts() {
        let r = reg();
        assert_eq!(r.artifacts.len(), 4);
        assert_eq!(r.artifacts[0].param("cap"), Some(64));
        assert_eq!(r.artifacts[0].inputs[0].shape, vec![32, 64]);
    }

    #[test]
    fn select_smallest_sufficient_cap() {
        let r = reg();
        assert_eq!(r.select("gcoo", 256, 50).unwrap().name, "gcoo_n256_cap64");
        assert_eq!(r.select("gcoo", 256, 65).unwrap().name, "gcoo_n256_cap256");
        assert_eq!(r.select("gcoo", 256, 64).unwrap().name, "gcoo_n256_cap64");
    }

    #[test]
    fn select_errors_when_nothing_fits() {
        let r = reg();
        assert!(matches!(
            r.select("gcoo", 256, 1000),
            Err(RuntimeError::NoVariant { .. })
        ));
        assert!(r.select("gcoo", 1024, 1).is_err());
    }

    #[test]
    fn dense_has_no_capacity_constraint() {
        let r = reg();
        assert_eq!(r.select("dense_xla", 256, usize::MAX).unwrap().name, "dense_xla_n256");
    }

    #[test]
    fn sizes_and_fit() {
        let r = reg();
        assert_eq!(r.sizes("gcoo"), vec![256, 512]);
        assert_eq!(r.fit_size("gcoo", 100), Some(256));
        assert_eq!(r.fit_size("gcoo", 256), Some(256));
        assert_eq!(r.fit_size("gcoo", 300), Some(512));
        assert_eq!(r.fit_size("gcoo", 9999), None);
    }

    #[test]
    fn bad_manifest_rejected() {
        assert!(Registry::from_manifest_json("{}", PathBuf::new()).is_err());
        assert!(Registry::from_manifest_json("not json", PathBuf::new()).is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // When `make artifacts` has run, the real manifest must parse and
        // contain every algorithm family at every exported size.
        if let Ok(r) = Registry::load("artifacts") {
            for algo in ["gcoo", "gcoo_noreuse", "csr", "dense_pallas", "dense_xla"] {
                assert!(!r.sizes(algo).is_empty(), "missing {algo}");
            }
        }
    }
}
