//! Minimal row-major f32 matrix (substrate shared by every module).

use crate::rng::Rng;

/// Row-major dense matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// iid standard-normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        Mat { rows, cols, data }
    }

    /// Reset to a `rows`×`cols` zero matrix **in place**, reusing the
    /// backing allocation when it is large enough. This is the arena
    /// primitive behind the per-worker serving workspaces: steady-state
    /// requests at a stable execution size never allocate.
    pub fn zero_into(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// In-place pad: make `self` the `m`×`m` zero-padded copy of `src`
    /// (top-left block), reusing `self`'s allocation.
    pub fn pad_from(&mut self, src: &Mat, m: usize) {
        assert!(src.rows <= m && src.cols <= m, "pad target smaller than source");
        self.zero_into(m, m);
        for i in 0..src.rows {
            self.row_mut(i)[..src.cols].copy_from_slice(src.row(i));
        }
    }

    /// In-place trim: make `self` the top-left `n`×`n` block of `src`,
    /// reusing `self`'s allocation.
    pub fn trim_from(&mut self, src: &Mat, n: usize) {
        assert!(n <= src.rows && n <= src.cols, "trim larger than source");
        self.zero_into(n, n);
        for i in 0..n {
            self.row_mut(i).copy_from_slice(&src.row(i)[..n]);
        }
    }

    /// Decode a raw little-endian f32 payload (wire protocol v3) straight
    /// into this matrix's backing buffer, reusing the allocation like
    /// [`Mat::zero_into`]: a connection-scoped scratch `Mat` reaches
    /// steady state with zero allocation and no intermediate value tree.
    /// Rejects length mismatches; finiteness is the caller's contract
    /// (the protocol boundary screens each float as it decodes).
    pub fn fill_from_le_bytes(
        &mut self,
        rows: usize,
        cols: usize,
        bytes: &[u8],
    ) -> Result<(), String> {
        if bytes.len() != rows * cols * 4 {
            return Err(format!(
                "payload of {} bytes is not {rows}x{cols} little-endian f32s",
                bytes.len()
            ));
        }
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.reserve(rows * cols);
        self.data.extend(
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
        Ok(())
    }

    /// Encode the element buffer as raw little-endian f32 bytes (the wire
    /// protocol v3 operand payload; row-major, bit-faithful per element).
    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data.len() * 4);
        for x in &self.data {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Number of exactly-zero entries over total entries.
    pub fn sparsity(&self) -> f64 {
        let nz = self.data.iter().filter(|v| **v != 0.0).count();
        1.0 - nz as f64 / self.data.len() as f64
    }

    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }

    /// Reference dense matmul (the rust-side oracle; O(n^3) naive-with-rows,
    /// used for verification, never on a benchmarked hot path).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "inner dimension mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for l in 0..self.cols {
                let a = self[(i, l)];
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(l);
                let orow = out.row_mut(i);
                for j in 0..other.cols {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// Max absolute elementwise difference.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// allclose with mixed relative/absolute tolerance.
    pub fn allclose(&self, other: &Mat, rtol: f32, atol: f32) -> bool {
        if (self.rows, self.cols) != (other.rows, other.cols) {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Simple percentile over a sorted copy (used by metrics/bench).
pub fn percentile(samples: &[f64], pct: f64) -> f64 {
    assert!(!samples.is_empty());
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (pct / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_round_trip() {
        let mut m = Mat::zeros(3, 4);
        m[(2, 3)] = 7.5;
        assert_eq!(m[(2, 3)], 7.5);
        assert_eq!(m.row(2)[3], 7.5);
    }

    #[test]
    fn eye_matmul_is_identity() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(8, 8, &mut rng);
        let out = Mat::eye(8).matmul(&a);
        assert!(out.allclose(&a, 1e-6, 1e-6));
    }

    #[test]
    fn matmul_known_values() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.matmul(&b).data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_rectangular() {
        let a = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Mat::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![4.0, 5.0]);
    }

    #[test]
    fn sparsity_counts_exact_zeros() {
        let m = Mat::from_vec(2, 2, vec![0.0, 1.0, 0.0, 2.0]);
        assert_eq!(m.sparsity(), 0.5);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn pad_trim_in_place_round_trip() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(5, 5, &mut rng);
        let mut padded = Mat::zeros(0, 0);
        padded.pad_from(&a, 8);
        assert_eq!((padded.rows, padded.cols), (8, 8));
        assert_eq!(padded[(4, 4)], a[(4, 4)]);
        assert_eq!(padded[(7, 7)], 0.0);
        let mut back = Mat::zeros(0, 0);
        back.trim_from(&padded, 5);
        assert_eq!(back, a);
    }

    #[test]
    fn zero_into_reuses_allocation() {
        let mut m = Mat::zeros(16, 16);
        let ptr = m.data.as_ptr();
        m[(3, 3)] = 9.0;
        m.zero_into(8, 8); // shrink: same buffer, fully zeroed
        assert_eq!((m.rows, m.cols), (8, 8));
        assert!(m.data.iter().all(|&x| x == 0.0));
        assert_eq!(m.data.as_ptr(), ptr);
        // pad_from at the same target size must not reallocate either.
        let src = Mat::eye(4);
        m.pad_from(&src, 8);
        assert_eq!(m.data.as_ptr(), ptr);
        assert_eq!(m[(2, 2)], 1.0);
        assert_eq!(m[(6, 6)], 0.0);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let m = Mat::randn(5, 7, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn percentile_basics() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    #[should_panic]
    fn matmul_dim_mismatch_panics() {
        Mat::zeros(2, 3).matmul(&Mat::zeros(2, 2));
    }

    #[test]
    fn le_bytes_round_trip_is_bit_faithful() {
        let mut rng = Rng::new(11);
        let m = Mat::randn(6, 5, &mut rng);
        let bytes = m.to_le_bytes();
        assert_eq!(bytes.len(), 6 * 5 * 4);
        let mut back = Mat::zeros(0, 0);
        back.fill_from_le_bytes(6, 5, &bytes).unwrap();
        assert_eq!(back, m);
        // Bit-faithful even for values a text round trip could disturb:
        // negative zero, subnormals, and the f32 extremes.
        let edge = Mat::from_vec(1, 4, vec![-0.0, f32::MIN_POSITIVE / 2.0, f32::MAX, -f32::MAX]);
        let mut back = Mat::zeros(0, 0);
        back.fill_from_le_bytes(1, 4, &edge.to_le_bytes()).unwrap();
        for (a, b) in back.data.iter().zip(&edge.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn fill_from_le_bytes_reuses_allocation_and_checks_len() {
        let mut m = Mat::zeros(8, 8);
        let ptr = m.data.as_ptr();
        let src = Mat::eye(8);
        m.fill_from_le_bytes(8, 8, &src.to_le_bytes()).unwrap();
        assert_eq!(m, src);
        assert_eq!(m.data.as_ptr(), ptr, "steady-state decode must not allocate");
        assert!(m.fill_from_le_bytes(8, 8, &[0u8; 12]).is_err());
        assert!(m.fill_from_le_bytes(2, 2, &[0u8; 17]).is_err());
    }
}
