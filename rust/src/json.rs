//! Minimal JSON codec (substrate — the offline image has no serde).
//!
//! Supports the full JSON value model with the subset of escapes the repo
//! emits/consumes (manifest.json, the serving protocol, CSV-adjacent result
//! dumps). Parser is a straightforward recursive-descent with position-
//! annotated errors; writer is deterministic (object keys kept in insertion
//! order via `Vec<(String, Value)>`).

use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered object (no hashing: objects here are tiny).
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Build an object value fluently.
    pub fn obj() -> ObjBuilder {
        ObjBuilder(Vec::new())
    }
}

/// Fluent builder for object values.
pub struct ObjBuilder(Vec<(String, Value)>);

impl ObjBuilder {
    pub fn field(mut self, k: &str, v: impl Into<Value>) -> Self {
        self.0.push((k.to_string(), v.into()));
        self
    }
    pub fn build(self) -> Value {
        Value::Obj(self.0)
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(x: f64) -> Self {
        Value::Num(x)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Self {
        Value::Num(x as f64)
    }
}
impl From<u64> for Value {
    fn from(x: u64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<i64> for Value {
    fn from(x: i64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<Vec<Value>> for Value {
    fn from(xs: Vec<Value>) -> Self {
        Value::Arr(xs)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed, nothing else).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(kvs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // No surrogate-pair support: the repo never emits them.
                            out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = &self.b[self.pos..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.pos += chunk.len();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Serialize (compact form).
pub fn write(v: &Value) -> String {
    let mut out = String::new();
    write_into(v, &mut out);
    out
}

fn write_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Value::Str(s) => write_escaped(s, out),
        Value::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Value::Obj(kvs) => {
            out.push('{');
            for (i, (k, x)) in kvs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Value::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""line\nbreak \"q\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "line\nbreak \"q\" A");
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(vec![]));
    }

    #[test]
    fn errors_carry_position() {
        let e = parse("[1, ]").unwrap_err();
        assert!(e.pos >= 3, "{e}");
        assert!(parse("").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("[1] junk").is_err());
    }

    #[test]
    fn round_trip() {
        let v = Value::obj()
            .field("name", "gcoo_n256")
            .field("n", 256usize)
            .field("ok", true)
            .field("ratio", 1.5f64)
            .field("tags", Value::Arr(vec!["a".into(), "b".into()]))
            .build();
        let text = write(&v);
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn round_trip_special_strings() {
        let v = Value::Str("quote\" slash\\ nl\n tab\t".into());
        assert_eq!(parse(&write(&v)).unwrap(), v);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(write(&Value::Num(7.0)), "7");
        assert_eq!(write(&Value::Num(7.5)), "7.5");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
            "schema": 1,
            "artifacts": [
                {"name": "gcoo_n256_p8_tb128_cap64", "algo": "gcoo", "n": 256,
                 "params": {"p": 8, "tb": 128, "cap": 64},
                 "inputs": [{"name": "values", "dtype": "float32", "shape": [32, 64]}],
                 "file": "gcoo_n256_p8_tb128_cap64.hlo.txt"}
            ]
        }"#;
        let v = parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("n").unwrap().as_usize().unwrap(), 256);
        assert_eq!(
            arts[0].get("params").unwrap().get("cap").unwrap().as_usize().unwrap(),
            64
        );
    }

    #[test]
    fn unicode_pass_through() {
        let v = parse("\"héllo ↦\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ↦");
        assert_eq!(parse(&write(&v)).unwrap(), v);
    }
}
