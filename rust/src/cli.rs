//! Command-line argument parser substrate (the offline image has no clap).
//!
//! Model: `binary <subcommand> [--flag value]... [--switch]...`. Parsed into
//! an [`Args`] bag with typed accessors and unknown-flag rejection against a
//! declared spec.

use std::collections::HashMap;

/// Declared flag.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    values: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected number, got {v:?}")),
        }
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// Parse argv (without the program name) against the declared flags.
pub fn parse(argv: &[String], flags: &[FlagSpec]) -> Result<Args, String> {
    let mut args = Args::default();
    let mut it = argv.iter().peekable();
    if let Some(first) = it.peek() {
        if !first.starts_with("--") {
            args.subcommand = it.next().unwrap().clone();
        }
    }
    while let Some(tok) = it.next() {
        let name = tok
            .strip_prefix("--")
            .ok_or_else(|| format!("unexpected positional argument {tok:?}"))?;
        // support --name=value
        let (name, inline_val) = match name.split_once('=') {
            Some((n, v)) => (n, Some(v.to_string())),
            None => (name, None),
        };
        let spec = flags
            .iter()
            .find(|f| f.name == name)
            .ok_or_else(|| format!("unknown flag --{name}"))?;
        if spec.takes_value {
            let val = match inline_val {
                Some(v) => v,
                None => it
                    .next()
                    .ok_or_else(|| format!("--{name} requires a value"))?
                    .clone(),
            };
            args.values.insert(name.to_string(), val);
        } else {
            if inline_val.is_some() {
                return Err(format!("--{name} does not take a value"));
            }
            args.switches.push(name.to_string());
        }
    }
    Ok(args)
}

/// Render a usage block for the declared flags.
pub fn usage(program: &str, subcommands: &[(&str, &str)], flags: &[FlagSpec]) -> String {
    let mut out = format!("usage: {program} <subcommand> [flags]\n\nsubcommands:\n");
    for (name, help) in subcommands {
        out.push_str(&format!("  {name:<12} {help}\n"));
    }
    out.push_str("\nflags:\n");
    for f in flags {
        let v = if f.takes_value { " <value>" } else { "" };
        out.push_str(&format!("  --{}{v:<10} {}\n", f.name, f.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags() -> Vec<FlagSpec> {
        vec![
            FlagSpec { name: "n", takes_value: true, help: "dimension" },
            FlagSpec { name: "sparsity", takes_value: true, help: "sparsity" },
            FlagSpec { name: "verify", takes_value: false, help: "check result" },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = parse(&sv(&["run", "--n", "256", "--verify"]), &flags()).unwrap();
        assert_eq!(a.subcommand, "run");
        assert_eq!(a.get_usize("n", 0).unwrap(), 256);
        assert!(a.has("verify"));
        assert!(!a.has("other"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&sv(&["run", "--sparsity=0.99"]), &flags()).unwrap();
        assert_eq!(a.get_f64("sparsity", 0.0).unwrap(), 0.99);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&sv(&["run"]), &flags()).unwrap();
        assert_eq!(a.get_usize("n", 512).unwrap(), 512);
        assert_eq!(a.get_str("missing", "x"), "x");
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(parse(&sv(&["run", "--bogus", "1"]), &flags()).is_err());
        assert!(parse(&sv(&["run", "--n"]), &flags()).is_err());
        assert!(parse(&sv(&["run", "stray"]), &flags()).is_err());
        assert!(parse(&sv(&["run", "--verify=1"]), &flags()).is_err());
        assert!(parse(&sv(&["run", "--n", "abc"]), &flags()).unwrap().get_usize("n", 0).is_err());
    }

    #[test]
    fn no_subcommand_is_empty() {
        let a = parse(&sv(&["--verify"]), &flags()).unwrap();
        assert_eq!(a.subcommand, "");
        assert!(a.has("verify"));
    }

    #[test]
    fn usage_renders() {
        let u = usage("gcoospdm", &[("run", "run one SpDM")], &flags());
        assert!(u.contains("run one SpDM"));
        assert!(u.contains("--sparsity"));
    }
}
