//! Parallel dense→sparse conversion (paper Algorithm 1) with the EO/KC
//! timing split of Fig 13.
//!
//! The paper's conversion is a two-step GPU kernel (count nnz per group,
//! then scatter); here it is a two-pass multi-threaded CPU routine with the
//! same structure: pass 1 counts per-band nonzeros (parallel over bands) and
//! prefix-sums `gIdxes`; pass 2 scatters entries into the concatenated
//! arrays (parallel over bands, each band writing its disjoint slice).

use std::time::Instant;

use crate::exec::scoped_for;
use crate::ndarray::Mat;
use crate::sparse::{Csr, Ell, FormatError, Gcoo, GcooPadded};

/// Timing breakdown for Fig 13: EO = alloc + convert; KC = kernel compute.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConvertTiming {
    pub alloc_s: f64,
    pub convert_s: f64,
}

impl ConvertTiming {
    /// Extra overhead (the paper's EO).
    pub fn eo(&self) -> f64 {
        self.alloc_s + self.convert_s
    }
}

/// Parallel Algorithm 1: dense → GCOO with `threads` workers.
pub fn dense_to_gcoo_parallel(a: &Mat, p: usize, threads: usize) -> (Gcoo, ConvertTiming) {
    assert!(p > 0);
    let g = a.rows.div_ceil(p);

    // --- Step 1: count nnz per group (parallel scan of A) ---
    let t0 = Instant::now();
    let mut nnz_per_group = vec![0u32; g];
    {
        let chunks: Vec<&mut [u32]> = nnz_per_group.chunks_mut(1).collect();
        // chunks_mut(1) gives one &mut per band; move into closures by index
        drop(chunks);
    }
    let counts: Vec<u32> = crate::exec::par_map(g, threads, |gi| {
        let lo = gi * p;
        let hi = ((gi + 1) * p).min(a.rows);
        let mut c = 0u32;
        for i in lo..hi {
            c += a.row(i).iter().filter(|v| **v != 0.0).count() as u32;
        }
        c
    });
    nnz_per_group.copy_from_slice(&counts);
    let mut g_idxes = vec![0u32; g];
    for gi in 1..g {
        g_idxes[gi] = g_idxes[gi - 1] + nnz_per_group[gi - 1];
    }
    let total: usize = nnz_per_group.iter().map(|&x| x as usize).sum();
    let count_s = t0.elapsed().as_secs_f64();

    // --- allocate (the paper's "memory allocation" EO component) ---
    let t1 = Instant::now();
    let mut vals = vec![0.0f32; total];
    let mut rows = vec![0u32; total];
    let mut cols = vec![0u32; total];
    let alloc_s = t1.elapsed().as_secs_f64();

    // --- Step 2: scatter (parallel over bands; disjoint output slices) ---
    let t2 = Instant::now();
    {
        // Split the output arrays at the band boundaries so each worker
        // owns its slices exclusively.
        let mut val_slices: Vec<&mut [f32]> = Vec::with_capacity(g);
        let mut row_slices: Vec<&mut [u32]> = Vec::with_capacity(g);
        let mut col_slices: Vec<&mut [u32]> = Vec::with_capacity(g);
        {
            let (mut vrest, mut rrest, mut crest) =
                (vals.as_mut_slice(), rows.as_mut_slice(), cols.as_mut_slice());
            for gi in 0..g {
                let len = nnz_per_group[gi] as usize;
                let (vh, vt) = vrest.split_at_mut(len);
                let (rh, rt) = rrest.split_at_mut(len);
                let (ch, ct) = crest.split_at_mut(len);
                val_slices.push(vh);
                row_slices.push(rh);
                col_slices.push(ch);
                vrest = vt;
                rrest = rt;
                crest = ct;
            }
        }
        // Interior mutability-free parallelism: move slices into a Vec of
        // Options and hand each band's slices to exactly one worker.
        let mut work: Vec<Option<(&mut [f32], &mut [u32], &mut [u32])>> = val_slices
            .into_iter()
            .zip(row_slices)
            .zip(col_slices)
            .map(|((v, r), c)| Some((v, r, c)))
            .collect();
        let work_ptr = std::sync::Mutex::new(&mut work);
        scoped_for(g, threads, |range| {
            // Per-worker scratch, reused across its bands (perf §L3: the
            // original column-major band walk read A at stride n — cache
            // hostile; collecting row-major then sorting the band's few
            // entries by (col, row) is ~4x faster at the paper's sparsity).
            let mut scratch: Vec<(u32, u32, f32)> = Vec::new();
            for gi in range {
                let (v, r, c) = {
                    let mut guard = work_ptr.lock().unwrap();
                    guard[gi].take().unwrap()
                };
                let lo = gi * p;
                let hi = ((gi + 1) * p).min(a.rows);
                scratch.clear();
                for i in lo..hi {
                    let local = (i - lo) as u32;
                    for (j, &x) in a.row(i).iter().enumerate() {
                        if x != 0.0 {
                            scratch.push((j as u32, local, x));
                        }
                    }
                }
                scratch.sort_unstable_by_key(|&(col, row, _)| (col, row));
                debug_assert_eq!(scratch.len(), v.len());
                for (k, &(col, row, x)) in scratch.iter().enumerate() {
                    v[k] = x;
                    r[k] = row;
                    c[k] = col;
                }
            }
        });
    }
    let scatter_s = t2.elapsed().as_secs_f64();

    let gcoo = Gcoo {
        n_rows: a.rows,
        n_cols: a.cols,
        p,
        vals,
        rows,
        cols,
        g_idxes,
        nnz_per_group,
    };
    (gcoo, ConvertTiming { alloc_s, convert_s: count_s + scatter_s })
}

/// Dense → padded device GCOO, end to end, with timing.
pub fn dense_to_gcoo_padded(
    a: &Mat,
    p: usize,
    cap: usize,
    threads: usize,
) -> Result<(GcooPadded, ConvertTiming), FormatError> {
    let (gcoo, mut timing) = dense_to_gcoo_parallel(a, p, threads);
    let t0 = Instant::now();
    let padded = gcoo.pad(cap)?;
    timing.convert_s += t0.elapsed().as_secs_f64();
    Ok((padded, timing))
}

/// Dense → padded CSR (ELL) with timing (the cuSPARSE-side EO of Fig 13).
pub fn dense_to_ell(a: &Mat, rowcap: usize) -> Result<(Ell, ConvertTiming), FormatError> {
    let t0 = Instant::now();
    let csr = Csr::from_dense(a);
    let convert = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let ell = Ell::from_csr(&csr, rowcap)?;
    let alloc = t1.elapsed().as_secs_f64();
    Ok((ell, ConvertTiming { alloc_s: alloc, convert_s: convert }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Rng;
    use crate::sparse::ToDense;

    #[test]
    fn parallel_matches_sequential() {
        let mut rng = Rng::new(1);
        let a = gen::uniform(96, 0.9, &mut rng);
        let (par, _t) = dense_to_gcoo_parallel(&a, 8, 4);
        let seq = Gcoo::from_dense(&a, 8);
        assert_eq!(par, seq);
    }

    #[test]
    fn single_thread_works() {
        let mut rng = Rng::new(2);
        let a = gen::uniform(32, 0.8, &mut rng);
        let (par, _t) = dense_to_gcoo_parallel(&a, 8, 1);
        assert_eq!(par.to_dense(), a);
    }

    #[test]
    fn ragged_band_count() {
        let mut rng = Rng::new(3);
        let a = gen::uniform(30, 0.7, &mut rng); // 30 rows, p=8
        let (par, _t) = dense_to_gcoo_parallel(&a, 8, 3);
        par.validate().unwrap();
        assert_eq!(par.to_dense(), a);
    }

    #[test]
    fn padded_round_trip_and_timing_positive() {
        let mut rng = Rng::new(4);
        let a = gen::uniform(64, 0.9, &mut rng);
        let (padded, timing) = dense_to_gcoo_padded(&a, 8, 8 * 64, 4).unwrap();
        assert_eq!(padded.g, 8);
        assert!(timing.eo() > 0.0);
    }

    #[test]
    fn padded_capacity_error_propagates() {
        let mut rng = Rng::new(5);
        let a = gen::uniform(64, 0.5, &mut rng);
        assert!(dense_to_gcoo_padded(&a, 8, 2, 4).is_err());
    }

    #[test]
    fn ell_conversion() {
        let mut rng = Rng::new(6);
        let a = gen::uniform(64, 0.9, &mut rng);
        let (ell, timing) = dense_to_ell(&a, 64).unwrap();
        assert_eq!(ell.to_dense(), a);
        assert!(timing.eo() >= 0.0);
    }
}
