//! Parallel dense→sparse conversion (paper Algorithm 1) with the EO/KC
//! timing split of Fig 13.
//!
//! The paper's conversion is a two-step GPU kernel (count nnz per group,
//! then scatter); here it is a two-pass multi-threaded CPU routine with the
//! same structure: pass 1 counts per-band nonzeros (parallel over bands) and
//! prefix-sums `gIdxes`; pass 2 scatters entries into the concatenated
//! arrays (parallel over bands, each band writing its disjoint slice).

use std::time::Instant;

use crate::exec::scoped_for;
use crate::ndarray::Mat;
use crate::sparse::{Csr, Ell, FormatError, Gcoo, GcooPadded};

/// Timing breakdown for Fig 13: EO = alloc + convert; KC = kernel compute.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConvertTiming {
    pub alloc_s: f64,
    pub convert_s: f64,
}

impl ConvertTiming {
    /// Extra overhead (the paper's EO).
    pub fn eo(&self) -> f64 {
        self.alloc_s + self.convert_s
    }
}

/// Single-pass statistics over A — Algorithm 1's counting pass fused with
/// the serving stats scan. One walk over every element yields sparsity,
/// the per-row maximum (ELL row capacity), and the per-band nnz counts
/// (GCOO band capacities) that the scatter pass then reuses verbatim, so
/// planning never triggers a conversion and conversion never re-counts.
///
/// Band counts are independent of the execution padding: padding A from
/// `n` to `n_exec` appends all-zero rows/columns, which add no nonzeros
/// and leave every existing band's count unchanged.
#[derive(Clone, Debug)]
pub struct AStats {
    pub rows: usize,
    pub cols: usize,
    /// Band height the counts were taken at.
    pub p: usize,
    pub nnz: usize,
    pub max_row_nnz: usize,
    /// Nonzeros per band of `p` consecutive rows (paper nnzPerGroup).
    pub nnz_per_band: Vec<u32>,
}

impl AStats {
    pub fn sparsity(&self) -> f64 {
        let total = self.rows * self.cols;
        if total == 0 {
            return 1.0;
        }
        1.0 - self.nnz as f64 / total as f64
    }

    /// Largest per-band nnz — the GCOO device capacity the request needs.
    pub fn max_band_nnz(&self) -> usize {
        self.nnz_per_band.iter().copied().max().unwrap_or(0) as usize
    }
}

/// The fused stats/counting pass (parallel over bands for large matrices;
/// small ones scan serially — fork/join spawn cost would dominate the
/// walk, and every request pays this pass).
pub fn scan_stats(a: &Mat, p: usize, threads: usize) -> AStats {
    assert!(p > 0);
    let g = a.rows.div_ceil(p);
    let band_counts = |gi: usize| -> (u32, u32) {
        let lo = gi * p;
        let hi = ((gi + 1) * p).min(a.rows);
        let mut band = 0u32;
        let mut max_row = 0u32;
        for i in lo..hi {
            let rn = a.row(i).iter().filter(|v| **v != 0.0).count() as u32;
            band += rn;
            max_row = max_row.max(rn);
        }
        (band, max_row)
    };
    let serial = threads <= 1 || a.rows * a.cols < (1 << 20);
    let per_band: Vec<(u32, u32)> = if serial {
        (0..g).map(band_counts).collect()
    } else {
        crate::exec::par_map(g, threads, band_counts)
    };
    let nnz_per_band: Vec<u32> = per_band.iter().map(|x| x.0).collect();
    AStats {
        rows: a.rows,
        cols: a.cols,
        p,
        nnz: nnz_per_band.iter().map(|&x| x as usize).sum(),
        max_row_nnz: per_band.iter().map(|x| x.1).max().unwrap_or(0) as usize,
        nnz_per_band,
    }
}

/// Collect one band's nonzeros into `scratch` as `(col, band-local row,
/// val)`, sorted by `(col, row)` — **the** intra-band ordering the
/// bv-reuse scan of Algorithm 2 and the cross-language fixtures depend on
/// (DESIGN.md §3). Shared by every scatter path so the ordering invariant
/// lives in exactly one place.
fn collect_band_sorted(a: &Mat, lo: usize, hi: usize, scratch: &mut Vec<(u32, u32, f32)>) {
    scratch.clear();
    for i in lo..hi {
        let local = (i - lo) as u32;
        for (j, &x) in a.row(i).iter().enumerate() {
            if x != 0.0 {
                scratch.push((j as u32, local, x));
            }
        }
    }
    scratch.sort_unstable_by_key(|&(col, row, _)| (col, row));
}

/// Algorithm 1's scatter pass fused with device padding: write A's nonzeros
/// directly into `(g = n_exec/p, cap)` GCOO slabs for an artifact of size
/// `n_exec ≥ a.rows`, reusing the band counts from [`scan_stats`]. The
/// padded A is never materialized (rows `a.rows..n_exec` are implicit
/// zeros) and no intermediate [`Gcoo`] is built — this is the one and only
/// conversion of A on the serving path, and under fused batching
/// (`pool::process_batch_ws`) its cost is paid once per shape-affine batch
/// rather than once per request: the resulting slabs feed a single wide
/// kernel over the batch's stacked B operands. The output buffers are
/// resized in place, so a per-worker workspace reaches a steady state with
/// **zero per-request allocation** on the A side.
pub fn dense_to_slabs_into(
    a: &Mat,
    stats: &AStats,
    n_exec: usize,
    cap: usize,
    threads: usize,
    vals: &mut Vec<f32>,
    rows: &mut Vec<i32>,
    cols: &mut Vec<i32>,
) -> Result<(), FormatError> {
    let p = stats.p;
    debug_assert_eq!(stats.rows, a.rows);
    let need = stats.max_band_nnz();
    if need > cap {
        return Err(FormatError::CapacityExceeded {
            which: "gcoo band".into(),
            needed: need,
            cap,
        });
    }
    if n_exec < a.rows {
        return Err(FormatError::Invalid(format!(
            "n_exec {n_exec} smaller than matrix rows {}",
            a.rows
        )));
    }
    let g = n_exec.div_ceil(p);
    vals.clear();
    vals.resize(g * cap, 0.0);
    rows.clear();
    rows.resize(g * cap, 0);
    cols.clear();
    cols.resize(g * cap, 0);
    if cap == 0 || g == 0 {
        return Ok(());
    }
    // Bands past a.rows hold only padding zeros — nothing to scatter.
    let live_bands = a.rows.div_ceil(p).min(g);
    // Same disjoint-slice hand-off as `dense_to_gcoo_parallel`: each band
    // owns its cap-sized chunk of every slab.
    let mut work: Vec<Option<(&mut [f32], &mut [i32], &mut [i32])>> = vals
        .chunks_mut(cap)
        .zip(rows.chunks_mut(cap))
        .zip(cols.chunks_mut(cap))
        .map(|((v, r), c)| Some((v, r, c)))
        .collect();
    let work_ptr = std::sync::Mutex::new(&mut work);
    scoped_for(live_bands, threads, |range| {
        let mut scratch: Vec<(u32, u32, f32)> = Vec::new();
        for gi in range {
            let (v, r, c) = {
                let mut guard = work_ptr.lock().unwrap();
                guard[gi].take().unwrap()
            };
            collect_band_sorted(a, gi * p, ((gi + 1) * p).min(a.rows), &mut scratch);
            debug_assert_eq!(scratch.len(), stats.nnz_per_band[gi] as usize);
            for (k, &(col, row, x)) in scratch.iter().enumerate() {
                v[k] = x;
                r[k] = row as i32;
                c[k] = col as i32;
            }
        }
    });
    Ok(())
}

/// Dense → ELL slabs in place (the CSR-path analog of
/// [`dense_to_slabs_into`]): no padded A, no intermediate CSR. Rows past
/// `a.rows` are implicit zeros.
pub fn dense_to_ell_into(
    a: &Mat,
    n_exec: usize,
    rowcap: usize,
    vals: &mut Vec<f32>,
    cols: &mut Vec<i32>,
) -> Result<(), FormatError> {
    if n_exec < a.rows {
        return Err(FormatError::Invalid(format!(
            "n_exec {n_exec} smaller than matrix rows {}",
            a.rows
        )));
    }
    vals.clear();
    vals.resize(n_exec * rowcap, 0.0);
    cols.clear();
    cols.resize(n_exec * rowcap, 0);
    for i in 0..a.rows {
        let mut k = 0usize;
        for (j, &x) in a.row(i).iter().enumerate() {
            if x != 0.0 {
                if k == rowcap {
                    return Err(FormatError::CapacityExceeded {
                        which: "ell row".into(),
                        needed: a.row(i).iter().filter(|v| **v != 0.0).count(),
                        cap: rowcap,
                    });
                }
                vals[i * rowcap + k] = x;
                cols[i * rowcap + k] = j as i32;
                k += 1;
            }
        }
    }
    Ok(())
}

/// Dense → CMRS slabs in place (the CMRS-path analog of
/// [`dense_to_slabs_into`]): strips of `stats.p` consecutive rows are
/// round-robin interleaved directly into `(g = n_exec/p, cap)` slabs.
/// Strip height equals the band height, so [`scan_stats`]' per-band
/// counts are reused verbatim for the capacity check — no second stats
/// pass. Rows past `a.rows` are implicit zeros.
pub fn dense_to_cmrs_into(
    a: &Mat,
    stats: &AStats,
    n_exec: usize,
    cap: usize,
    vals: &mut Vec<f32>,
    rows: &mut Vec<i32>,
    cols: &mut Vec<i32>,
) -> Result<(), FormatError> {
    let p = stats.p;
    debug_assert_eq!(stats.rows, a.rows);
    let need = stats.max_band_nnz();
    if need > cap {
        return Err(FormatError::CapacityExceeded {
            which: "cmrs strip".into(),
            needed: need,
            cap,
        });
    }
    if n_exec < a.rows {
        return Err(FormatError::Invalid(format!(
            "n_exec {n_exec} smaller than matrix rows {}",
            a.rows
        )));
    }
    let g = n_exec.div_ceil(p);
    vals.clear();
    vals.resize(g * cap, 0.0);
    rows.clear();
    rows.resize(g * cap, 0);
    cols.clear();
    cols.resize(g * cap, 0);
    if cap == 0 || g == 0 {
        return Ok(());
    }
    let live_strips = a.rows.div_ceil(p).min(g);
    let mut lists: Vec<Vec<(u32, f32)>> = Vec::with_capacity(p);
    for si in 0..live_strips {
        let lo = si * p;
        let hi = ((si + 1) * p).min(a.rows);
        lists.clear();
        // Per-row (col, val) lists; a row-major walk gives ascending cols.
        for i in lo..hi {
            lists.push(
                a.row(i)
                    .iter()
                    .enumerate()
                    .filter(|(_, v)| **v != 0.0)
                    .map(|(j, &v)| (j as u32, v))
                    .collect(),
            );
        }
        let deepest = lists.iter().map(|l| l.len()).max().unwrap_or(0);
        let mut k = si * cap;
        for idx in 0..deepest {
            for (r, list) in lists.iter().enumerate() {
                if let Some(&(c, v)) = list.get(idx) {
                    vals[k] = v;
                    rows[k] = r as i32;
                    cols[k] = c as i32;
                    k += 1;
                }
            }
        }
        debug_assert_eq!(k - si * cap, stats.nnz_per_band[si] as usize);
    }
    Ok(())
}

/// Dense → row-split slabs in place. Each row's entries (ascending
/// column) are cut into `cap`-sized segments emitted in row order;
/// returns the segment count (the slab geometry is content-dependent).
/// Any `cap ≥ 1` fits any matrix, so there is no capacity failure mode.
/// Rows past `a.rows` are implicit zeros and produce no segments.
pub fn dense_to_rowsplit_into(
    a: &Mat,
    n_exec: usize,
    cap: usize,
    vals: &mut Vec<f32>,
    seg_rows: &mut Vec<i32>,
    cols: &mut Vec<i32>,
) -> Result<usize, FormatError> {
    if cap == 0 {
        return Err(FormatError::Invalid("rowsplit: segment capacity 0".into()));
    }
    if n_exec < a.rows {
        return Err(FormatError::Invalid(format!(
            "n_exec {n_exec} smaller than matrix rows {}",
            a.rows
        )));
    }
    // Pass 1: per-row nnz → total segment count (mirrors scan_stats' row
    // walk; row-split keys on per-row rather than per-band counts).
    let segs: usize = (0..a.rows)
        .map(|i| a.row(i).iter().filter(|v| **v != 0.0).count().div_ceil(cap))
        .sum();
    vals.clear();
    vals.resize(segs * cap, 0.0);
    cols.clear();
    cols.resize(segs * cap, 0);
    seg_rows.clear();
    seg_rows.resize(segs, 0);
    // Pass 2: scatter.
    let mut s = 0usize;
    for i in 0..a.rows {
        let mut in_seg = 0usize;
        for (j, &v) in a.row(i).iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            if in_seg == 0 {
                seg_rows[s] = i as i32;
                s += 1;
            }
            vals[(s - 1) * cap + in_seg] = v;
            cols[(s - 1) * cap + in_seg] = j as i32;
            in_seg += 1;
            if in_seg == cap {
                in_seg = 0;
            }
        }
    }
    debug_assert_eq!(s, segs);
    Ok(segs)
}

/// Parallel Algorithm 1: dense → GCOO with `threads` workers.
pub fn dense_to_gcoo_parallel(a: &Mat, p: usize, threads: usize) -> (Gcoo, ConvertTiming) {
    assert!(p > 0);
    let g = a.rows.div_ceil(p);

    // --- Step 1: count nnz per group (parallel scan of A) ---
    let t0 = Instant::now();
    let mut nnz_per_group = vec![0u32; g];
    {
        let chunks: Vec<&mut [u32]> = nnz_per_group.chunks_mut(1).collect();
        // chunks_mut(1) gives one &mut per band; move into closures by index
        drop(chunks);
    }
    let counts: Vec<u32> = crate::exec::par_map(g, threads, |gi| {
        let lo = gi * p;
        let hi = ((gi + 1) * p).min(a.rows);
        let mut c = 0u32;
        for i in lo..hi {
            c += a.row(i).iter().filter(|v| **v != 0.0).count() as u32;
        }
        c
    });
    nnz_per_group.copy_from_slice(&counts);
    let mut g_idxes = vec![0u32; g];
    for gi in 1..g {
        g_idxes[gi] = g_idxes[gi - 1] + nnz_per_group[gi - 1];
    }
    let total: usize = nnz_per_group.iter().map(|&x| x as usize).sum();
    let count_s = t0.elapsed().as_secs_f64();

    // --- allocate (the paper's "memory allocation" EO component) ---
    let t1 = Instant::now();
    let mut vals = vec![0.0f32; total];
    let mut rows = vec![0u32; total];
    let mut cols = vec![0u32; total];
    let alloc_s = t1.elapsed().as_secs_f64();

    // --- Step 2: scatter (parallel over bands; disjoint output slices) ---
    let t2 = Instant::now();
    {
        // Split the output arrays at the band boundaries so each worker
        // owns its slices exclusively.
        let mut val_slices: Vec<&mut [f32]> = Vec::with_capacity(g);
        let mut row_slices: Vec<&mut [u32]> = Vec::with_capacity(g);
        let mut col_slices: Vec<&mut [u32]> = Vec::with_capacity(g);
        {
            let (mut vrest, mut rrest, mut crest) =
                (vals.as_mut_slice(), rows.as_mut_slice(), cols.as_mut_slice());
            for gi in 0..g {
                let len = nnz_per_group[gi] as usize;
                let (vh, vt) = vrest.split_at_mut(len);
                let (rh, rt) = rrest.split_at_mut(len);
                let (ch, ct) = crest.split_at_mut(len);
                val_slices.push(vh);
                row_slices.push(rh);
                col_slices.push(ch);
                vrest = vt;
                rrest = rt;
                crest = ct;
            }
        }
        // Interior mutability-free parallelism: move slices into a Vec of
        // Options and hand each band's slices to exactly one worker.
        let mut work: Vec<Option<(&mut [f32], &mut [u32], &mut [u32])>> = val_slices
            .into_iter()
            .zip(row_slices)
            .zip(col_slices)
            .map(|((v, r), c)| Some((v, r, c)))
            .collect();
        let work_ptr = std::sync::Mutex::new(&mut work);
        scoped_for(g, threads, |range| {
            // Per-worker scratch, reused across its bands (perf §L3: the
            // original column-major band walk read A at stride n — cache
            // hostile; collecting row-major then sorting the band's few
            // entries by (col, row) is ~4x faster at the paper's sparsity).
            let mut scratch: Vec<(u32, u32, f32)> = Vec::new();
            for gi in range {
                let (v, r, c) = {
                    let mut guard = work_ptr.lock().unwrap();
                    guard[gi].take().unwrap()
                };
                collect_band_sorted(a, gi * p, ((gi + 1) * p).min(a.rows), &mut scratch);
                debug_assert_eq!(scratch.len(), v.len());
                for (k, &(col, row, x)) in scratch.iter().enumerate() {
                    v[k] = x;
                    r[k] = row;
                    c[k] = col;
                }
            }
        });
    }
    let scatter_s = t2.elapsed().as_secs_f64();

    let gcoo = Gcoo {
        n_rows: a.rows,
        n_cols: a.cols,
        p,
        vals,
        rows,
        cols,
        g_idxes,
        nnz_per_group,
    };
    (gcoo, ConvertTiming { alloc_s, convert_s: count_s + scatter_s })
}

/// Dense → padded device GCOO, end to end, with timing.
pub fn dense_to_gcoo_padded(
    a: &Mat,
    p: usize,
    cap: usize,
    threads: usize,
) -> Result<(GcooPadded, ConvertTiming), FormatError> {
    let (gcoo, mut timing) = dense_to_gcoo_parallel(a, p, threads);
    let t0 = Instant::now();
    let padded = gcoo.pad(cap)?;
    timing.convert_s += t0.elapsed().as_secs_f64();
    Ok((padded, timing))
}

/// Dense → padded CSR (ELL) with timing (the cuSPARSE-side EO of Fig 13).
pub fn dense_to_ell(a: &Mat, rowcap: usize) -> Result<(Ell, ConvertTiming), FormatError> {
    let t0 = Instant::now();
    let csr = Csr::from_dense(a);
    let convert = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let ell = Ell::from_csr(&csr, rowcap)?;
    let alloc = t1.elapsed().as_secs_f64();
    Ok((ell, ConvertTiming { alloc_s: alloc, convert_s: convert }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Rng;
    use crate::sparse::ToDense;

    #[test]
    fn parallel_matches_sequential() {
        let mut rng = Rng::new(1);
        let a = gen::uniform(96, 0.9, &mut rng);
        let (par, _t) = dense_to_gcoo_parallel(&a, 8, 4);
        let seq = Gcoo::from_dense(&a, 8);
        assert_eq!(par, seq);
    }

    #[test]
    fn single_thread_works() {
        let mut rng = Rng::new(2);
        let a = gen::uniform(32, 0.8, &mut rng);
        let (par, _t) = dense_to_gcoo_parallel(&a, 8, 1);
        assert_eq!(par.to_dense(), a);
    }

    #[test]
    fn ragged_band_count() {
        let mut rng = Rng::new(3);
        let a = gen::uniform(30, 0.7, &mut rng); // 30 rows, p=8
        let (par, _t) = dense_to_gcoo_parallel(&a, 8, 3);
        par.validate().unwrap();
        assert_eq!(par.to_dense(), a);
    }

    #[test]
    fn padded_round_trip_and_timing_positive() {
        let mut rng = Rng::new(4);
        let a = gen::uniform(64, 0.9, &mut rng);
        let (padded, timing) = dense_to_gcoo_padded(&a, 8, 8 * 64, 4).unwrap();
        assert_eq!(padded.g, 8);
        assert!(timing.eo() > 0.0);
    }

    #[test]
    fn padded_capacity_error_propagates() {
        let mut rng = Rng::new(5);
        let a = gen::uniform(64, 0.5, &mut rng);
        assert!(dense_to_gcoo_padded(&a, 8, 2, 4).is_err());
    }

    #[test]
    fn ell_conversion() {
        let mut rng = Rng::new(6);
        let a = gen::uniform(64, 0.9, &mut rng);
        let (ell, timing) = dense_to_ell(&a, 64).unwrap();
        assert_eq!(ell.to_dense(), a);
        assert!(timing.eo() >= 0.0);
    }

    #[test]
    fn scan_stats_matches_direct_counts() {
        let mut rng = Rng::new(7);
        let a = gen::uniform(50, 0.85, &mut rng); // ragged: 50 rows, p=8
        let stats = scan_stats(&a, 8, 3);
        assert_eq!(stats.nnz, a.nnz());
        assert!((stats.sparsity() - a.sparsity()).abs() < 1e-12);
        let gcoo = Gcoo::from_dense(&a, 8);
        assert_eq!(
            stats.nnz_per_band, gcoo.nnz_per_group,
            "fused counts must equal Algorithm 1 pass 1"
        );
        assert_eq!(stats.max_band_nnz(), gcoo.max_group_nnz());
        let max_row = (0..a.rows)
            .map(|i| a.row(i).iter().filter(|v| **v != 0.0).count())
            .max()
            .unwrap();
        assert_eq!(stats.max_row_nnz, max_row);
    }

    #[test]
    fn slabs_into_equals_convert_then_pad() {
        let mut rng = Rng::new(8);
        let a = gen::uniform(64, 0.9, &mut rng);
        let stats = scan_stats(&a, 8, 2);
        let cap = stats.max_band_nnz() + 3;
        let (mut v, mut r, mut c) = (Vec::new(), Vec::new(), Vec::new());
        dense_to_slabs_into(&a, &stats, 64, cap, 3, &mut v, &mut r, &mut c).unwrap();
        let reference = Gcoo::from_dense(&a, 8).pad(cap).unwrap();
        assert_eq!(v, reference.vals);
        assert_eq!(r, reference.rows);
        assert_eq!(c, reference.cols);
    }

    #[test]
    fn slabs_into_pads_without_materializing_a() {
        // n=30 request executed at n_exec=40: trailing bands are implicit
        // zeros and the result must equal converting the padded matrix.
        let mut rng = Rng::new(9);
        let a = gen::uniform(30, 0.8, &mut rng);
        let stats = scan_stats(&a, 8, 2);
        let cap = stats.max_band_nnz().max(1);
        let (mut v, mut r, mut c) = (Vec::new(), Vec::new(), Vec::new());
        dense_to_slabs_into(&a, &stats, 40, cap, 2, &mut v, &mut r, &mut c).unwrap();
        let mut a_pad = Mat::zeros(40, 40);
        for i in 0..30 {
            a_pad.row_mut(i)[..30].copy_from_slice(a.row(i));
        }
        let reference = Gcoo::from_dense(&a_pad, 8).pad(cap).unwrap();
        assert_eq!((v.len(), reference.g), (reference.vals.len(), 5));
        assert_eq!(v, reference.vals);
        assert_eq!(r, reference.rows);
        assert_eq!(c, reference.cols);
    }

    #[test]
    fn slabs_into_reuses_buffers_and_checks_capacity() {
        let mut rng = Rng::new(10);
        let a = gen::uniform(32, 0.9, &mut rng);
        let stats = scan_stats(&a, 8, 1);
        let (mut v, mut r, mut c) = (Vec::new(), Vec::new(), Vec::new());
        let cap = stats.max_band_nnz().max(1);
        dense_to_slabs_into(&a, &stats, 32, cap, 1, &mut v, &mut r, &mut c).unwrap();
        let ptr_before = v.as_ptr();
        let cap_before = v.capacity();
        // Second conversion at the same geometry must not reallocate.
        dense_to_slabs_into(&a, &stats, 32, cap, 1, &mut v, &mut r, &mut c).unwrap();
        assert_eq!(v.as_ptr(), ptr_before);
        assert_eq!(v.capacity(), cap_before);
        // Capacity overflow is a typed error, not a panic.
        assert!(matches!(
            dense_to_slabs_into(&a, &stats, 32, cap - 1, 1, &mut v, &mut r, &mut c),
            Err(FormatError::CapacityExceeded { .. })
        ));
        // n_exec below the matrix size is rejected.
        assert!(dense_to_slabs_into(&a, &stats, 16, cap, 1, &mut v, &mut r, &mut c).is_err());
    }

    #[test]
    fn cmrs_into_equals_convert_then_pad() {
        use crate::sparse::Cmrs;
        let mut rng = Rng::new(12);
        let a = gen::power_law_rows(64, 0.9, &mut rng);
        let stats = scan_stats(&a, 8, 2);
        let cap = stats.max_band_nnz() + 3;
        let (mut v, mut r, mut c) = (Vec::new(), Vec::new(), Vec::new());
        dense_to_cmrs_into(&a, &stats, 64, cap, &mut v, &mut r, &mut c).unwrap();
        let reference = Cmrs::from_dense(&a, 8).pad(cap).unwrap();
        assert_eq!(v, reference.vals);
        assert_eq!(r, reference.rows);
        assert_eq!(c, reference.cols);
        // Padded execution size: trailing strips are all-zero slots.
        dense_to_cmrs_into(&a, &stats, 80, cap, &mut v, &mut r, &mut c).unwrap();
        assert_eq!(v.len(), 10 * cap);
        assert_eq!(&v[..8 * cap], &reference.vals[..]);
        assert!(v[8 * cap..].iter().all(|&x| x == 0.0));
        // Capacity overflow is a typed error; undersized n_exec rejected.
        assert!(matches!(
            dense_to_cmrs_into(&a, &stats, 64, stats.max_band_nnz() - 1, &mut v, &mut r, &mut c),
            Err(FormatError::CapacityExceeded { .. })
        ));
        assert!(dense_to_cmrs_into(&a, &stats, 32, cap, &mut v, &mut r, &mut c).is_err());
    }

    #[test]
    fn rowsplit_into_equals_convert_then_pad() {
        use crate::sparse::RowSplit;
        let mut rng = Rng::new(13);
        let a = gen::power_law_rows(64, 0.9, &mut rng);
        let (mut v, mut sr, mut c) = (Vec::new(), Vec::new(), Vec::new());
        for cap in [1usize, 4, 64] {
            let segs = dense_to_rowsplit_into(&a, 64, cap, &mut v, &mut sr, &mut c).unwrap();
            let reference = RowSplit::from_dense(&a, cap).unwrap().pad();
            assert_eq!(segs, reference.segs, "cap {cap}");
            assert_eq!(v, reference.vals);
            assert_eq!(sr, reference.seg_rows);
            assert_eq!(c, reference.cols);
        }
        // Padded execution size adds no segments (implicit zero rows).
        let segs_64 = dense_to_rowsplit_into(&a, 64, 4, &mut v, &mut sr, &mut c).unwrap();
        let segs_80 = dense_to_rowsplit_into(&a, 80, 4, &mut v, &mut sr, &mut c).unwrap();
        assert_eq!(segs_64, segs_80);
        // Zero capacity and undersized n_exec are typed errors.
        assert!(dense_to_rowsplit_into(&a, 64, 0, &mut v, &mut sr, &mut c).is_err());
        assert!(dense_to_rowsplit_into(&a, 32, 4, &mut v, &mut sr, &mut c).is_err());
    }

    #[test]
    fn ell_into_matches_from_csr() {
        let mut rng = Rng::new(11);
        let a = gen::uniform(48, 0.9, &mut rng);
        let csr = Csr::from_dense(&a);
        let rowcap = csr.max_row_nnz() + 2;
        let reference = Ell::from_csr(&csr, rowcap).unwrap();
        let (mut v, mut c) = (Vec::new(), Vec::new());
        dense_to_ell_into(&a, 48, rowcap, &mut v, &mut c).unwrap();
        assert_eq!(v, reference.vals);
        assert_eq!(c, reference.cols);
        // Padded execution size: extra rows are all-zero slots.
        dense_to_ell_into(&a, 50, rowcap, &mut v, &mut c).unwrap();
        assert_eq!(v.len(), 50 * rowcap);
        assert_eq!(&v[..48 * rowcap], &reference.vals[..]);
        assert!(v[48 * rowcap..].iter().all(|&x| x == 0.0));
        // Row overflow is a typed error.
        assert!(matches!(
            dense_to_ell_into(&a, 48, csr.max_row_nnz() - 1, &mut v, &mut c),
            Err(FormatError::CapacityExceeded { .. })
        ));
    }
}
