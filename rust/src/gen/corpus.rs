//! The Fig-4 corpus: a synthetic stand-in for the SuiteSparse square
//! matrices the paper evaluates (2694 matrices, sparsity ∈ [0.98, 0.999999],
//! n ∈ [64, 36720]).
//!
//! We reproduce the *distributional axes* that decide GCOO wins/losses:
//! a mixture over structural families, log-uniform dimensions, and the
//! paper's sparsity range. Sizes are scaled down by default (simulating a
//! 36720² walk per matrix × 2694 matrices is pointless on CPU); the spec is
//! explicit so benches can scale up.

use super::patterns::Pattern;
use crate::rng::Rng;

/// Corpus parameters (defaults mirror the paper, scaled).
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub count: usize,
    pub min_n: usize,
    pub max_n: usize,
    pub min_sparsity: f64,
    pub max_sparsity: f64,
    pub seed: u64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec {
            count: 2694,               // the paper's matrix count
            min_n: 64,
            max_n: 4096,               // paper: 36720 (scaled for CPU walkers)
            min_sparsity: 0.98,
            max_sparsity: 0.999999,
            seed: 0x5EED_C0DE,
        }
    }
}

/// One corpus member: enough metadata to regenerate the matrix on demand.
#[derive(Clone, Debug)]
pub struct CorpusEntry {
    pub id: usize,
    pub pattern: Pattern,
    pub n: usize,
    pub sparsity: f64,
    pub seed: u64,
}

impl CorpusEntry {
    pub fn materialize(&self) -> crate::ndarray::Mat {
        let mut rng = Rng::new(self.seed);
        super::patterns::generate(self.pattern, self.n, self.sparsity, &mut rng)
    }
}

/// SuiteSparse-like family mixture: applications skew toward banded/FEM and
/// diagonal-ish structure, with a graph tail plus a thin slice of the
/// adversarial families (extreme skew / ragged bands). Weights sum to 100.
const MIXTURE: [(Pattern, u64); 9] = [
    (Pattern::Banded, 28),
    (Pattern::Diagonal, 19),
    (Pattern::BlockDiagonal, 14),
    (Pattern::PowerLawRows, 14),
    (Pattern::Uniform, 14),
    (Pattern::DenseColumns, 5),
    (Pattern::ZipfRows, 2),
    (Pattern::HeavyRows, 2),
    (Pattern::RaggedBands, 2),
];

/// Generate corpus *metadata* (cheap); materialize entries lazily.
pub fn corpus(spec: &CorpusSpec) -> Vec<CorpusEntry> {
    let mut rng = Rng::new(spec.seed);
    let ln_lo = (spec.min_n as f64).ln();
    let ln_hi = (spec.max_n as f64).ln();
    (0..spec.count)
        .map(|id| {
            // log-uniform n (SuiteSparse dims span 3 decades)
            let n = (ln_lo + rng.next_f64() * (ln_hi - ln_lo)).exp().round() as usize;
            // sparsity: log-uniform in (1 - s) over the paper's range
            let d_lo = (1.0 - spec.max_sparsity).ln();
            let d_hi = (1.0 - spec.min_sparsity).ln();
            let density = (d_lo + rng.next_f64() * (d_hi - d_lo)).exp();
            let sparsity = 1.0 - density;
            // mixture draw
            let mut ticket = rng.below(100);
            let mut pattern = Pattern::Uniform;
            for (p, w) in MIXTURE {
                if ticket < w {
                    pattern = p;
                    break;
                }
                ticket -= w;
            }
            CorpusEntry { id, pattern, n: n.max(spec.min_n), sparsity, seed: rng.fork(id as u64).next_u64() }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_respects_spec_ranges() {
        let spec = CorpusSpec { count: 500, ..Default::default() };
        let entries = corpus(&spec);
        assert_eq!(entries.len(), 500);
        for e in &entries {
            assert!((spec.min_n..=spec.max_n + 1).contains(&e.n), "n={}", e.n);
            assert!(e.sparsity >= spec.min_sparsity - 1e-9);
            assert!(e.sparsity <= spec.max_sparsity + 1e-9);
        }
    }

    #[test]
    fn corpus_is_deterministic() {
        let spec = CorpusSpec { count: 50, ..Default::default() };
        let a = corpus(&spec);
        let b = corpus(&spec);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.n, x.seed, x.pattern), (y.n, y.seed, y.pattern));
        }
    }

    #[test]
    fn corpus_covers_every_family() {
        let entries = corpus(&CorpusSpec { count: 300, ..Default::default() });
        for p in Pattern::ALL {
            assert!(
                entries.iter().any(|e| e.pattern == p),
                "family {} missing from corpus",
                p.name()
            );
        }
    }

    #[test]
    fn materialize_small_entry() {
        let entries = corpus(&CorpusSpec {
            count: 5,
            min_n: 32,
            max_n: 64,
            ..Default::default()
        });
        let m = entries[0].materialize();
        assert_eq!(m.rows, entries[0].n);
        assert!(m.sparsity() > 0.5);
    }

    #[test]
    fn size_distribution_spans_decades() {
        let entries = corpus(&CorpusSpec { count: 1000, ..Default::default() });
        let small = entries.iter().filter(|e| e.n < 256).count();
        let large = entries.iter().filter(|e| e.n > 1024).count();
        assert!(small > 100, "too few small matrices: {small}");
        assert!(large > 100, "too few large matrices: {large}");
    }
}
