//! Table III — the paper's 14 selected matrices, synthesized.
//!
//! We cannot download SuiteSparse, so each matrix is reproduced from its
//! documented (n, sparsity, problem domain) with a structural family chosen
//! to match the domain (DESIGN.md §2). The paper's Fig-5 narrative is pinned
//! by structure: nemeth11 / plbuckle / fpga_dcop_01 are diagonal-dominated
//! (GCOO's loss cases); the graph/economics matrices are irregular.

use super::patterns::Pattern;
use crate::ndarray::Mat;
use crate::rng::Rng;

/// Metadata row from Table III plus our structural assignment.
#[derive(Clone, Copy, Debug)]
pub struct SelectedSpec {
    pub name: &'static str,
    /// The paper's dimension (materialization may scale it down).
    pub paper_n: usize,
    /// Density (the paper's "Sparsity" column is actually density nnz/n²).
    pub density: f64,
    pub problem: &'static str,
    pub pattern: Pattern,
}

/// All 14 rows of Table III.
pub const SELECTED: [SelectedSpec; 14] = [
    SelectedSpec { name: "nemeth11", paper_n: 9506, density: 2.31e-3, problem: "Quantum Chemistry", pattern: Pattern::Diagonal },
    SelectedSpec { name: "human_gene1", paper_n: 22283, density: 2.49e-2, problem: "Undirected Weighted Graph", pattern: Pattern::PowerLawRows },
    SelectedSpec { name: "Lederberg", paper_n: 8843, density: 5.32e-4, problem: "Directed Multigraph", pattern: Pattern::PowerLawRows },
    SelectedSpec { name: "m3plates", paper_n: 11107, density: 5.38e-5, problem: "Acoustics", pattern: Pattern::BlockDiagonal },
    SelectedSpec { name: "aug3dcqp", paper_n: 35543, density: 6.16e-5, problem: "2D/3D", pattern: Pattern::Banded },
    SelectedSpec { name: "Trefethen_20000b", paper_n: 19999, density: 7.18e-4, problem: "Combinatorial", pattern: Pattern::Banded },
    SelectedSpec { name: "ex37", paper_n: 3565, density: 5.32e-3, problem: "Computational Fluid", pattern: Pattern::Banded },
    SelectedSpec { name: "g7jac020sc", paper_n: 5850, density: 1.33e-3, problem: "Economic", pattern: Pattern::Uniform },
    SelectedSpec { name: "LF10000", paper_n: 19998, density: 1.50e-4, problem: "Model Reduction", pattern: Pattern::Banded },
    SelectedSpec { name: "epb2", paper_n: 25228, density: 2.75e-4, problem: "Thermal", pattern: Pattern::Banded },
    SelectedSpec { name: "plbuckle", paper_n: 1282, density: 9.71e-3, problem: "Structural", pattern: Pattern::Diagonal },
    SelectedSpec { name: "wang3", paper_n: 26064, density: 2.61e-4, problem: "Semiconductor Device", pattern: Pattern::Banded },
    SelectedSpec { name: "fpga_dcop_01", paper_n: 1220, density: 3.96e-3, problem: "Circuit Simulation", pattern: Pattern::Diagonal },
    SelectedSpec { name: "viscoplastic2_C_1", paper_n: 32769, density: 3.55e-4, problem: "Materials", pattern: Pattern::BlockDiagonal },
];

impl SelectedSpec {
    pub fn sparsity(&self) -> f64 {
        1.0 - self.density
    }

    /// n used for materialization: the paper's n clamped to `max_n`
    /// (density is preserved, which is what drives the walkers).
    pub fn scaled_n(&self, max_n: usize) -> usize {
        self.paper_n.min(max_n)
    }

    pub fn materialize(&self, max_n: usize, seed: u64) -> Mat {
        let n = self.scaled_n(max_n);
        let mut rng = Rng::new(seed ^ fxhash(self.name));
        super::patterns::generate(self.pattern, n, self.sparsity(), &mut rng)
    }

    /// True for the matrices the paper reports as cuSPARSE wins (diagonal
    /// structure defeats bv reuse).
    pub fn expected_gcoo_loss(&self) -> bool {
        self.pattern == Pattern::Diagonal
    }
}

/// Materialize all 14 (scaled).
pub fn selected_matrices(max_n: usize, seed: u64) -> Vec<(SelectedSpec, Mat)> {
    SELECTED.iter().map(|s| (*s, s.materialize(max_n, seed))).collect()
}

/// Tiny deterministic string hash (FNV-1a) for per-name seed derivation.
fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_has_14_rows() {
        assert_eq!(SELECTED.len(), 14);
        let names: std::collections::HashSet<_> = SELECTED.iter().map(|s| s.name).collect();
        assert_eq!(names.len(), 14, "duplicate names");
    }

    #[test]
    fn loss_cases_are_the_papers_three() {
        let losses: Vec<&str> = SELECTED
            .iter()
            .filter(|s| s.expected_gcoo_loss())
            .map(|s| s.name)
            .collect();
        assert_eq!(losses, vec!["nemeth11", "plbuckle", "fpga_dcop_01"]);
    }

    #[test]
    fn densities_match_paper_magnitudes() {
        for s in &SELECTED {
            assert!(s.density > 0.0 && s.density < 0.03, "{}: {}", s.name, s.density);
            assert!(s.sparsity() > 0.97);
        }
    }

    #[test]
    fn materialize_scaled_preserves_density() {
        let s = &SELECTED[1]; // human_gene1, densest
        let m = s.materialize(512, 7);
        assert_eq!(m.rows, 512);
        let got = 1.0 - m.sparsity();
        assert!(
            (got - s.density).abs() / s.density < 0.5,
            "density {got} vs {}",
            s.density
        );
    }

    #[test]
    fn small_paper_matrices_not_scaled() {
        let s = SELECTED.iter().find(|s| s.name == "plbuckle").unwrap();
        assert_eq!(s.scaled_n(2048), 1282);
    }

    #[test]
    fn materialization_deterministic_per_name() {
        let a = SELECTED[0].materialize(256, 1);
        let b = SELECTED[0].materialize(256, 1);
        assert_eq!(a, b);
        let c = SELECTED[3].materialize(256, 1);
        assert_ne!(a.data, c.data);
    }
}
