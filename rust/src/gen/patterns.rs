//! Structural matrix families.
//!
//! Values mirror `python ref.random_sparse`: standard-normal with tiny
//! magnitudes pushed away from zero so nnz is stable across conversions.

use crate::ndarray::Mat;
use crate::rng::Rng;

/// A named structural family, used by corpus generation and the CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pattern {
    /// iid nonzero placement — the paper's random corpus.
    Uniform,
    /// nonzeros on/near the diagonal — the paper's loss case (no bv reuse).
    Diagonal,
    /// nonzeros inside a ± bandwidth around the diagonal.
    Banded,
    /// dense blocks on the diagonal (structural/FEM-like).
    BlockDiagonal,
    /// per-row nnz follows a power law (graph/web-like).
    PowerLawRows,
    /// a few fully-dense columns — maximal bv reuse.
    DenseColumns,
    /// extreme Zipf row lengths (exponent 2) — nnz concentrated in a
    /// handful of rows, the nnz-split family's target case.
    ZipfRows,
    /// strictly bimodal rows: a few fully-dense rows over a single-entry
    /// background — maximal row-length variance (CMRS's target case).
    HeavyRows,
    /// alternating dense / near-empty row strips — per-band nnz varies by
    /// an order of magnitude, stressing GCOO's uniform band cap.
    RaggedBands,
}

impl Pattern {
    pub const ALL: [Pattern; 9] = [
        Pattern::Uniform,
        Pattern::Diagonal,
        Pattern::Banded,
        Pattern::BlockDiagonal,
        Pattern::PowerLawRows,
        Pattern::DenseColumns,
        Pattern::ZipfRows,
        Pattern::HeavyRows,
        Pattern::RaggedBands,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Pattern::Uniform => "uniform",
            Pattern::Diagonal => "diagonal",
            Pattern::Banded => "banded",
            Pattern::BlockDiagonal => "block_diagonal",
            Pattern::PowerLawRows => "power_law_rows",
            Pattern::DenseColumns => "dense_columns",
            Pattern::ZipfRows => "zipf_rows",
            Pattern::HeavyRows => "heavy_rows",
            Pattern::RaggedBands => "ragged_bands",
        }
    }

    pub fn from_name(name: &str) -> Option<Pattern> {
        Pattern::ALL.iter().copied().find(|p| p.name() == name)
    }
}

/// Dispatch on the family.
pub fn generate(pattern: Pattern, n: usize, sparsity: f64, rng: &mut Rng) -> Mat {
    match pattern {
        Pattern::Uniform => uniform(n, sparsity, rng),
        Pattern::Diagonal => diagonal(n, sparsity, rng),
        Pattern::Banded => banded(n, sparsity, rng),
        Pattern::BlockDiagonal => block_diagonal(n, sparsity, rng),
        Pattern::PowerLawRows => power_law_rows(n, sparsity, rng),
        Pattern::DenseColumns => dense_columns(n, sparsity, rng),
        Pattern::ZipfRows => zipf_rows(n, sparsity, rng),
        Pattern::HeavyRows => heavy_rows(n, sparsity, rng),
        Pattern::RaggedBands => ragged_bands(n, sparsity, rng),
    }
}

/// iid placement with per-entry probability `1 - sparsity`.
pub fn uniform(n: usize, sparsity: f64, rng: &mut Rng) -> Mat {
    let mut m = Mat::zeros(n, n);
    let p = 1.0 - sparsity;
    for v in m.data.iter_mut() {
        if rng.coin(p) {
            *v = rng.nonzero_value();
        }
    }
    m
}

/// Nonzeros packed onto diagonals nearest the main one until the nnz budget
/// (≈ (1−s)·n²) is spent — the nemeth11/plbuckle-style structure.
pub fn diagonal(n: usize, sparsity: f64, rng: &mut Rng) -> Mat {
    let mut m = Mat::zeros(n, n);
    let budget = (((1.0 - sparsity) * (n * n) as f64).round() as usize).max(1);
    let mut placed = 0;
    let mut d = 0i64;
    while placed < budget && (d.unsigned_abs() as usize) < n {
        for offset in [d, -d] {
            if offset == 0 && d != 0 {
                continue;
            }
            let len = n - offset.unsigned_abs() as usize;
            for i in 0..len {
                if placed >= budget {
                    break;
                }
                let (r, c) = if offset >= 0 {
                    (i, i + offset as usize)
                } else {
                    (i + (-offset) as usize, i)
                };
                if m[(r, c)] == 0.0 {
                    m[(r, c)] = rng.nonzero_value();
                    placed += 1;
                }
            }
        }
        d += 1;
    }
    m
}

/// Random placement restricted to a band sized so expected nnz matches.
pub fn banded(n: usize, sparsity: f64, rng: &mut Rng) -> Mat {
    let budget = (1.0 - sparsity) * (n * n) as f64;
    // band entries ≈ n·(2h+1); fill ~1/3 of the band.
    let fill = 0.34;
    let half = (((budget / fill) / n as f64 - 1.0) / 2.0).max(0.0).round() as usize;
    let half = half.min(n - 1);
    let mut m = Mat::zeros(n, n);
    for i in 0..n {
        let lo = i.saturating_sub(half);
        let hi = (i + half + 1).min(n);
        for j in lo..hi {
            if rng.coin(fill) {
                m[(i, j)] = rng.nonzero_value();
            }
        }
    }
    m
}

/// Dense square blocks along the diagonal; block size chosen to hit the
/// nnz budget.
pub fn block_diagonal(n: usize, sparsity: f64, rng: &mut Rng) -> Mat {
    let budget = ((1.0 - sparsity) * (n * n) as f64).max(1.0);
    // k blocks of size b: nnz ≈ n·b ⇒ b ≈ budget / n.
    let b = ((budget / n as f64).round() as usize).clamp(1, n);
    let mut m = Mat::zeros(n, n);
    let mut start = 0;
    while start < n {
        let end = (start + b).min(n);
        for i in start..end {
            for j in start..end {
                m[(i, j)] = rng.nonzero_value();
            }
        }
        start = end;
    }
    m
}

/// Zipf-ish row lengths: a few heavy rows, many light rows (graph-like).
pub fn power_law_rows(n: usize, sparsity: f64, rng: &mut Rng) -> Mat {
    let budget = (((1.0 - sparsity) * (n * n) as f64).round() as usize).max(n);
    // weights ∝ 1/(rank+1); normalize to the budget.
    let weights: Vec<f64> = (0..n).map(|i| 1.0 / (i + 1) as f64).collect();
    let wsum: f64 = weights.iter().sum();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order); // heavy rows land at random positions
    let mut m = Mat::zeros(n, n);
    for (rank, &row) in order.iter().enumerate() {
        let k = ((budget as f64) * weights[rank] / wsum).round() as usize;
        let k = k.clamp(1, n);
        for j in rng.sample_indices(n, k) {
            m[(row, j)] = rng.nonzero_value();
        }
    }
    m
}

/// `k` fully-dense columns, k chosen from the nnz budget — maximal
/// same-column runs inside every band (GCOO's best case).
pub fn dense_columns(n: usize, sparsity: f64, rng: &mut Rng) -> Mat {
    let budget = ((1.0 - sparsity) * (n * n) as f64).max(1.0);
    let k = ((budget / n as f64).round() as usize).clamp(1, n);
    let mut m = Mat::zeros(n, n);
    for j in rng.sample_indices(n, k) {
        for i in 0..n {
            m[(i, j)] = rng.nonzero_value();
        }
    }
    m
}

/// Extreme Zipf row lengths (weights ∝ 1/rank², vs the plain power-law
/// family's 1/rank): the head rows absorb almost the whole nnz budget
/// while the tail collapses to one entry per row.
pub fn zipf_rows(n: usize, sparsity: f64, rng: &mut Rng) -> Mat {
    let budget = (((1.0 - sparsity) * (n * n) as f64).round() as usize).max(n);
    let weights: Vec<f64> = (0..n).map(|i| 1.0 / (((i + 1) * (i + 1)) as f64)).collect();
    let wsum: f64 = weights.iter().sum();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut m = Mat::zeros(n, n);
    for (rank, &row) in order.iter().enumerate() {
        let k = (((budget as f64) * weights[rank] / wsum).round() as usize).clamp(1, n);
        for j in rng.sample_indices(n, k) {
            m[(row, j)] = rng.nonzero_value();
        }
    }
    m
}

/// Strictly bimodal rows: `k` fully-dense rows (k from the nnz budget)
/// over a background of exactly one entry per remaining row — maximal
/// row-length variance with no middle ground.
pub fn heavy_rows(n: usize, sparsity: f64, rng: &mut Rng) -> Mat {
    let budget = (((1.0 - sparsity) * (n * n) as f64).round() as usize).max(n);
    // heavy·n + (n − heavy) ≈ budget ⇒ heavy ≈ (budget − n) / (n − 1).
    let heavy = (budget.saturating_sub(n) / n.saturating_sub(1).max(1)).clamp(1, n);
    let mut m = Mat::zeros(n, n);
    for i in rng.sample_indices(n, heavy) {
        for j in 0..n {
            m[(i, j)] = rng.nonzero_value();
        }
    }
    for i in 0..n {
        if m.row(i).iter().all(|v| *v == 0.0) {
            let j = rng.sample_indices(n, 1)[0];
            m[(i, j)] = rng.nonzero_value();
        }
    }
    m
}

/// Alternating dense / near-empty row strips of height 8: even strips
/// absorb ~9/10 of the nnz budget, so per-band nnz swings by roughly an
/// order of magnitude while total sparsity stays on target.
pub fn ragged_bands(n: usize, sparsity: f64, rng: &mut Rng) -> Mat {
    let budget = (1.0 - sparsity) * (n * n) as f64;
    let strip = 8usize.min(n.max(1));
    let strips = n.div_ceil(strip);
    let heavy_fill = (0.9 * budget / ((strips.div_ceil(2) * strip * n) as f64)).min(1.0);
    let light_fill = (0.1 * budget / (((strips / 2).max(1) * strip * n) as f64)).min(1.0);
    let mut m = Mat::zeros(n, n);
    for s in 0..strips {
        let fill = if s % 2 == 0 { heavy_fill } else { light_fill };
        for i in s * strip..((s + 1) * strip).min(n) {
            for j in 0..n {
                if rng.coin(fill) {
                    m[(i, j)] = rng.nonzero_value();
                }
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sparsity_close(m: &Mat, target: f64, tol: f64) {
        let actual = m.sparsity();
        assert!(
            (actual - target).abs() < tol,
            "sparsity {actual} vs target {target}"
        );
    }

    #[test]
    fn uniform_hits_target_sparsity() {
        let mut rng = Rng::new(1);
        sparsity_close(&uniform(128, 0.9, &mut rng), 0.9, 0.02);
        sparsity_close(&uniform(128, 0.99, &mut rng), 0.99, 0.01);
    }

    #[test]
    fn diagonal_mass_near_diagonal() {
        let mut rng = Rng::new(2);
        let m = diagonal(64, 0.95, &mut rng);
        sparsity_close(&m, 0.95, 0.02);
        for i in 0..64 {
            for j in 0..64 {
                if m[(i, j)] != 0.0 {
                    assert!(i.abs_diff(j) <= 3, "entry far off diagonal at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn banded_within_band() {
        let mut rng = Rng::new(3);
        let m = banded(64, 0.9, &mut rng);
        let mut max_off = 0usize;
        for i in 0..64 {
            for j in 0..64 {
                if m[(i, j)] != 0.0 {
                    max_off = max_off.max(i.abs_diff(j));
                }
            }
        }
        assert!(max_off <= 16, "bandwidth too wide: {max_off}");
        assert!(m.nnz() > 0);
    }

    #[test]
    fn block_diagonal_blocks_are_dense() {
        let mut rng = Rng::new(4);
        let m = block_diagonal(64, 0.9, &mut rng);
        // every nonzero's mirror within its block is nonzero
        sparsity_close(&m, 0.9, 0.05);
        for i in 0..64 {
            for j in 0..64 {
                if m[(i, j)] != 0.0 {
                    assert_ne!(m[(j, i)], 0.0, "block not symmetric-dense at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn power_law_rows_skewed() {
        let mut rng = Rng::new(5);
        let m = power_law_rows(128, 0.95, &mut rng);
        let mut lens: Vec<usize> =
            (0..128).map(|i| m.row(i).iter().filter(|v| **v != 0.0).count()).collect();
        lens.sort_unstable();
        // heaviest row should dominate the median by a wide margin
        assert!(lens[127] >= 4 * lens[64].max(1), "rows not skewed: {:?}", &lens[120..]);
        assert!(lens.iter().all(|&l| l >= 1), "every row has >= 1 entry");
    }

    #[test]
    fn dense_columns_are_dense() {
        let mut rng = Rng::new(6);
        let m = dense_columns(64, 0.95, &mut rng);
        let k = (0..64).filter(|&j| (0..64).all(|i| m[(i, j)] != 0.0)).count();
        assert!(k >= 1);
        assert_eq!(m.nnz(), k * 64, "all nonzeros must sit in full columns");
    }

    #[test]
    fn zipf_rows_head_dominates_p90() {
        let mut rng = Rng::new(8);
        let m = zipf_rows(128, 0.95, &mut rng);
        let mut lens: Vec<usize> =
            (0..128).map(|i| m.row(i).iter().filter(|v| **v != 0.0).count()).collect();
        assert!(lens.iter().all(|&l| l >= 1), "every row has >= 1 entry");
        lens.sort_unstable();
        // Steeper than power_law_rows: the head dominates even the 90th
        // percentile, not just the median.
        assert!(lens[127] >= 8 * lens[115].max(1), "head must dominate p90: {:?}", &lens[110..]);
    }

    #[test]
    fn heavy_rows_strictly_bimodal() {
        let mut rng = Rng::new(9);
        let m = heavy_rows(64, 0.9, &mut rng);
        let lens: Vec<usize> =
            (0..64).map(|i| m.row(i).iter().filter(|v| **v != 0.0).count()).collect();
        let dense = lens.iter().filter(|&&l| l == 64).count();
        let single = lens.iter().filter(|&&l| l == 1).count();
        assert!(dense >= 1, "at least one fully-dense row");
        assert_eq!(dense + single, 64, "every row is full or single-entry: {lens:?}");
        sparsity_close(&m, 0.9, 0.05);
    }

    #[test]
    fn ragged_bands_band_nnz_swings() {
        let mut rng = Rng::new(10);
        let m = ragged_bands(64, 0.9, &mut rng);
        let counts: Vec<usize> = (0..8)
            .map(|s| {
                (s * 8..(s + 1) * 8)
                    .map(|i| m.row(i).iter().filter(|v| **v != 0.0).count())
                    .sum()
            })
            .collect();
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max >= 4 * min.max(1), "strips must be ragged: {counts:?}");
        sparsity_close(&m, 0.9, 0.05);
    }

    #[test]
    fn generate_dispatch_covers_all() {
        let mut rng = Rng::new(7);
        for p in Pattern::ALL {
            let m = generate(p, 32, 0.9, &mut rng);
            assert!(m.nnz() > 0, "{} generated an empty matrix", p.name());
            assert_eq!(Pattern::from_name(p.name()), Some(p));
        }
        assert_eq!(Pattern::from_name("nope"), None);
    }

    #[test]
    fn generators_deterministic() {
        for p in Pattern::ALL {
            let a = generate(p, 32, 0.9, &mut Rng::new(42));
            let b = generate(p, 32, 0.9, &mut Rng::new(42));
            assert_eq!(a, b, "{} not deterministic", p.name());
        }
    }
}
