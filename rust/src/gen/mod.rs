//! Matrix generators — the workload side of every experiment.
//!
//! `patterns` provides the structural families (uniform, diagonal, banded,
//! block-diagonal, power-law rows); `corpus` builds the Fig-4 stand-in for
//! the SuiteSparse collection; `selected` synthesizes analogs of the paper's
//! 14 Table III matrices.

mod patterns;
mod corpus;
mod selected;

pub use patterns::{
    uniform, diagonal, banded, block_diagonal, power_law_rows, dense_columns, zipf_rows,
    heavy_rows, ragged_bands, Pattern, generate,
};
pub use corpus::{corpus, CorpusSpec, CorpusEntry};
pub use selected::{selected_matrices, SelectedSpec, SELECTED};
