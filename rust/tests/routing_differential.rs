//! Adaptive-routing differential lockdown (ISSUE 5): routing may change
//! **choices** — algo/artifact provenance, exploration, mid-stream route
//! flips — but never **results**.
//!
//! * The broad differential: adaptive routing (measured model +
//!   exploration + flips, live tuner) is **bitwise identical** to static
//!   routing across all 9 corpus patterns × {gcoo, csr, auto-dense} ×
//!   widths {1, 2, batch_max} × {n=64, n=60}, on both the inline and the
//!   registered-operand (handle) paths.
//! * The misroute convergence test: a sparse-by-the-numbers matrix whose
//!   scripted latencies favor dense is re-routed to the empirically
//!   faster plan, with the flip request index asserted **exactly**
//!   against a lock-step mirror of the tuner's pure functions — no
//!   sleeps, no wall-clock reads; every measured latency comes from the
//!   scripted fake clock.
//! * Trace-replay determinism: the same seed through a live coordinator
//!   twice produces identical flip schedules end to end.
//! * `explain` surfaces the routing table (candidates, versions,
//!   estimates) locally and over the wire.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use gcoospdm::coordinator::{
    explore_draw, process_batch_tuned, process_batch_ws, Algo, BatchJob, Coordinator,
    CoordinatorConfig, Metrics, ModelKey, OperandStore, ScriptedClock, SpdmRequest, TuneCtx,
    Tuner, TunerConfig,
};
use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::{Engine, Registry};
use gcoospdm::serve::{self, Client, ReplayOutcome, Server, ServerConfig, TraceSpec};

/// Stub registry at n=64 (two gcoo capacities, csr, dense) — the engine
/// only needs artifact files to exist.
fn registry_full() -> Registry {
    let dir = PathBuf::from("target/routing_differential_artifacts");
    std::fs::create_dir_all(&dir).expect("create stub artifact dir");
    std::fs::write(dir.join("stub.hlo.txt"), b"stub").expect("write stub artifact");
    let manifest = r#"{"artifacts": [
        {"name": "gcoo_n64_cap64", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "gcoo_n64_cap512", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "csr_n64_rowcap64", "algo": "csr", "n": 64,
         "params": {"rp": 8, "rowcap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "dense_xla_n64", "algo": "dense_xla", "n": 64,
         "params": {}, "inputs": [], "file": "stub.hlo.txt"}
    ]}"#;
    Registry::from_manifest_json(manifest, dir).expect("stub manifest parses")
}

/// Registry without a csr family: a gcoo-routed entry's one alternative is
/// dense — the two-candidate setup the flip tests script against.
fn registry_no_csr() -> Registry {
    let dir = PathBuf::from("target/routing_differential_artifacts");
    std::fs::create_dir_all(&dir).expect("create stub artifact dir");
    std::fs::write(dir.join("stub.hlo.txt"), b"stub").expect("write stub artifact");
    let manifest = r#"{"artifacts": [
        {"name": "gcoo_n64_cap64", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "gcoo_n64_cap512", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "dense_xla_n64", "algo": "dense_xla", "n": 64,
         "params": {}, "inputs": [], "file": "stub.hlo.txt"}
    ]}"#;
    Registry::from_manifest_json(manifest, dir).expect("stub manifest parses")
}

fn adaptive_tuning() -> TunerConfig {
    TunerConfig {
        enabled: true,
        alpha: 0.5,
        min_samples: 2,
        explore_every: 3,
        seed: 0xD1FF_5EED,
        register_refine_budget: 2,
    }
}

/// The broad acceptance differential: for every corpus pattern ×
/// {gcoo, csr, auto-dense} × widths {1, 2, batch_max} × {n=64, n=60},
/// three pipelines answer the same requests —
///   (1) static inline (`process_batch_ws`, no tuner),
///   (2) adaptive inline (live tuner: measured model + exploration),
///   (3) adaptive handle (registered entry: cached execution, exploration,
///       flips) —
/// and every response's C must be **bitwise identical** across all three.
/// The scripted fixed-step clock keeps adaptive choices deterministic; the
/// choices themselves (provenance) are free to differ — that is the point.
#[test]
fn adaptive_routing_bitwise_equals_static_across_corpus() {
    let reg = registry_full();
    let cfg_static = CoordinatorConfig::default();
    let cfg_adapt = CoordinatorConfig { tuning: adaptive_tuning(), ..Default::default() };
    let engine = Engine::new().unwrap();
    let mut ws = gcoospdm::coordinator::Workspace::new();
    let tuner = Tuner::new(cfg_adapt.tuning, Arc::new(ScriptedClock::new(vec![])));
    let store = OperandStore::new(cfg_adapt.store_budget_bytes);
    let metrics = Metrics::new();
    let tune = TuneCtx { tuner: &tuner, store: &store, metrics: &metrics };

    let widths = [1usize, 2, cfg_static.batch_max];
    let mut rng = Rng::new(0x0D1F);
    let mut cells = 0usize;
    for (pi, pattern) in gen::Pattern::ALL.iter().enumerate() {
        let n = if pi % 2 == 0 { 64 } else { 60 };
        // 0.95 sits below the paper crossover: auto routes dense, leaving
        // the sparse families to hints and to the adaptive model.
        let a = gen::generate(*pattern, n, 0.95, &mut rng);
        for hint in [Some(Algo::Gcoo), Some(Algo::Csr), None] {
            let entry = store.register(a.clone(), hint, &reg, &cfg_adapt).expect("put_a");
            assert_eq!(entry.version, 1);
            for &width in &widths {
                let bs: Vec<Mat> = (0..width).map(|_| Mat::randn(n, n, &mut rng)).collect();
                let mk_inline = |base: u64| -> Vec<SpdmRequest> {
                    bs.iter()
                        .enumerate()
                        .map(|(i, b)| {
                            let mut r = SpdmRequest::new(base + i as u64, a.clone(), b.clone());
                            r.algo_hint = hint;
                            r.verify = i == 0;
                            r
                        })
                        .collect()
                };
                let static_reqs = mk_inline(1000);
                let adapt_reqs = mk_inline(2000);
                let handle_reqs: Vec<SpdmRequest> = bs
                    .iter()
                    .enumerate()
                    .map(|(i, b)| {
                        let mut r =
                            SpdmRequest::for_handle(3000 + i as u64, entry.handle, b.clone());
                        r.a_sig = entry.sig; // what Coordinator::submit does
                        r.algo_hint = hint;
                        r.verify = i == 0;
                        r
                    })
                    .collect();

                let static_jobs: Vec<BatchJob<'_>> =
                    static_reqs.iter().map(|r| BatchJob::inline(r, Instant::now())).collect();
                let adapt_jobs: Vec<BatchJob<'_>> =
                    adapt_reqs.iter().map(|r| BatchJob::inline(r, Instant::now())).collect();
                let handle_jobs: Vec<BatchJob<'_>> = handle_reqs
                    .iter()
                    .map(|r| BatchJob { req: r, entry: Some(&*entry), enqueued: Instant::now() })
                    .collect();

                let s = process_batch_ws(&engine, &mut ws, &reg, &cfg_static, &static_jobs);
                let ad =
                    process_batch_tuned(&engine, &mut ws, &reg, &cfg_adapt, &adapt_jobs, Some(&tune));
                let h = process_batch_tuned(
                    &engine, &mut ws, &reg, &cfg_adapt, &handle_jobs, Some(&tune),
                );

                let ctx = format!("{}/{:?}/w{}/n{}", pattern.name(), hint, width, n);
                for i in 0..width {
                    assert!(s[i].ok(), "{ctx} static[{i}]: {:?}", s[i].error);
                    assert!(ad[i].ok(), "{ctx} adaptive[{i}]: {:?}", ad[i].error);
                    assert!(h[i].ok(), "{ctx} handle[{i}]: {:?}", h[i].error);
                    if i == 0 {
                        assert_eq!(s[i].verified, Some(true), "{ctx} oracle");
                    }
                    // The invariant: whatever route the tuner took, the
                    // numbers are the static pipeline's numbers, bit for
                    // bit — on both the inline and the handle path.
                    assert!(
                        ad[i].c == s[i].c,
                        "{ctx}[{i}]: adaptive inline C differs from static (adaptive ran {:?})",
                        ad[i].algo
                    );
                    assert!(
                        h[i].c == s[i].c,
                        "{ctx}[{i}]: adaptive handle C differs from static (handle ran {:?})",
                        h[i].algo
                    );
                    // Hinted traffic never engages the tuner: provenance
                    // must match static exactly.
                    if hint.is_some() {
                        assert_eq!(ad[i].algo, s[i].algo, "{ctx}[{i}] hinted provenance");
                        assert_eq!(h[i].algo, s[i].algo, "{ctx}[{i}] hinted handle provenance");
                    }
                }
                cells += 1;
            }
        }
    }
    assert_eq!(cells, 9 * 3 * 3, "full corpus × hint × width matrix covered");
}

/// Registry with gcoo plus exactly one exploration family (cmrs or
/// rowsplit): the two-candidate setup the family flip test scripts
/// against — the prior still routes gcoo, the measured model can only
/// flip to the new family.
fn registry_gcoo_plus(family: &str) -> Registry {
    let dir = PathBuf::from("target/routing_differential_artifacts");
    std::fs::create_dir_all(&dir).expect("create stub artifact dir");
    std::fs::write(dir.join("stub.hlo.txt"), b"stub").expect("write stub artifact");
    let extra = match family {
        "cmrs" => {
            r#"{"name": "cmrs_n64_cap512", "algo": "cmrs", "n": 64,
         "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"}"#
        }
        _ => {
            r#"{"name": "rowsplit_n64_cap64", "algo": "rowsplit", "n": 64,
         "params": {"cap": 64}, "inputs": [], "file": "stub.hlo.txt"}"#
        }
    };
    let manifest = format!(
        r#"{{"artifacts": [
        {{"name": "gcoo_n64_cap512", "algo": "gcoo", "n": 64,
         "params": {{"p": 8, "cap": 512}}, "inputs": [], "file": "stub.hlo.txt"}},
        {extra}
    ]}}"#
    );
    Registry::from_manifest_json(&manifest, dir).expect("stub manifest parses")
}

/// Satellite (ISSUE 10): the new families win on measurements. A matrix
/// the paper prior routes to gcoo, served under scripted latencies that
/// favor the exploration family 8×, flips to CMRS (then, in a second
/// scenario, to row-split) at the **exactly** mirrored request index —
/// and every response C stays bitwise identical to a static gcoo
/// coordinator across the flip.
#[test]
fn cmrs_and_rowsplit_beat_gcoo_with_exact_flip_index() {
    for (family, alt_algo) in [("cmrs", Algo::Cmrs), ("rowsplit", Algo::RowSplit)] {
        let tuning = TunerConfig {
            enabled: true,
            alpha: 0.5, // exactly representable: mirror math is exact
            min_samples: 2,
            explore_every: 3,
            seed: 0x5EED_CAFE,
            register_refine_budget: 0,
        };
        let cfg = CoordinatorConfig { workers: 1, tuning, ..Default::default() };
        let clock = Arc::new(ScriptedClock::new(vec![]));
        let coord = Coordinator::with_clock(
            Arc::new(registry_gcoo_plus(family)),
            cfg,
            Arc::<ScriptedClock>::clone(&clock),
        );
        let static_coord = Coordinator::new(
            Arc::new(registry_gcoo_plus(family)),
            CoordinatorConfig { workers: 1, ..Default::default() },
        );

        let mut rng = Rng::new(0x985);
        let a = gen::uniform(64, 0.985, &mut rng);
        let entry = coord.put_a(a.clone(), None).expect("put_a");
        assert_eq!(entry.plan.algo, Algo::Gcoo, "{family}: the prior routes gcoo");
        let algos: Vec<Algo> = entry.candidates.iter().map(|c| c.algo).collect();
        assert_eq!(algos, vec![Algo::Gcoo, alt_algo], "{family}: one alternative");
        let key = ModelKey::operand(entry.handle);

        // Scripted latencies (exact powers of two): gcoo 0.5 s, the new
        // family 0.0625 s — 8× faster per the fake clock.
        const LAT_GCOO: f64 = 0.5;
        const LAT_ALT: f64 = 0.0625;
        let mut mirror = Mirror { alpha: 0.5, min_samples: 2, est: HashMap::new() };
        let mut incumbent = Algo::Gcoo;
        let mut flip_at: Option<usize> = None;

        for i in 0..24usize {
            let alt = if incumbent == Algo::Gcoo { alt_algo } else { Algo::Gcoo };
            let draw = explore_draw(tuning.seed, key, i as u64, tuning.explore_every);
            let predicted = if draw { alt } else { incumbent };
            let lat = if predicted == Algo::Gcoo { LAT_GCOO } else { LAT_ALT };
            clock.push_latency(lat);

            let b = Mat::randn(64, 64, &mut rng);
            let mut req = SpdmRequest::for_handle(100 + i as u64, entry.handle, b.clone());
            req.verify = true;
            let resp = coord.run_sync(req);
            assert!(resp.ok(), "{family}[{i}] {:?}", resp.error);
            assert_eq!(resp.verified, Some(true), "{family}[{i}] oracle");
            assert_eq!(
                resp.algo, predicted,
                "{family}[{i}] live routing diverged from the pure-function mirror"
            );

            let sresp = static_coord.run_sync(SpdmRequest::new(500 + i as u64, a.clone(), b));
            assert_eq!(sresp.algo, Algo::Gcoo);
            assert!(
                resp.c == sresp.c,
                "{family}[{i}] C (ran {:?}) must be bitwise identical to static gcoo",
                resp.algo
            );

            mirror.observe(predicted, lat / 64.0);
            if let (Some(inc_m), Some(alt_m)) = (mirror.gated(incumbent), mirror.gated(alt)) {
                if alt_m < inc_m && flip_at.is_none() {
                    flip_at = Some(i);
                    incumbent = alt;
                }
            }
            let expected_flips = match flip_at {
                Some(f) if i >= f => 1,
                _ => 0,
            };
            assert_eq!(
                coord.snapshot().route_flips,
                expected_flips,
                "{family}[{i}] flip counter must transition exactly at the mirrored index"
            );
        }

        let flipped_at =
            flip_at.expect("family-favoring latencies must force a flip within K=24");
        assert_eq!(incumbent, alt_algo, "{family} wins the measured race");
        assert_eq!(
            coord.snapshot().route_flips,
            1,
            "{family}: exactly one flip, at request {flipped_at}"
        );
        let republished = coord
            .store()
            .entries_snapshot()
            .into_iter()
            .find(|e| e.handle == entry.handle)
            .expect("still resident");
        assert_eq!(republished.version, 2, "{family}: entry republished");
        assert_eq!(republished.plan.algo, alt_algo);
        assert_eq!(republished.plan.reason, "measured-flip");
        assert_eq!(entry.plan.algo, Algo::Gcoo, "{family}: pre-flip snapshot untouched");

        coord.shutdown();
        static_coord.shutdown();
    }
}

/// Lock-step mirror of the tuner's arithmetic: the same EWMA, the same
/// gate, the same strictly-less flip rule — over exactly-representable
/// latencies so f64 math matches the live model bit for bit.
struct Mirror {
    alpha: f64,
    min_samples: u64,
    est: HashMap<Algo, (f64, u64)>,
}

impl Mirror {
    fn observe(&mut self, algo: Algo, per_col: f64) {
        let e = self.est.entry(algo).or_insert((per_col, 0));
        e.0 += self.alpha * (per_col - e.0);
        e.1 += 1;
    }

    fn gated(&self, algo: Algo) -> Option<f64> {
        self.est.get(&algo).filter(|(_, n)| *n >= self.min_samples).map(|(m, _)| *m)
    }
}

/// Satellite 1 (convergence): a matrix that is sparse by the numbers
/// (0.985 ≥ the 0.98 crossover, so the prior registers it gcoo) but whose
/// scripted latencies are dense-favoring is re-routed to the empirically
/// faster plan — with the flip request index asserted **exactly** against
/// the mirror, the provenance flip observed in the responses, and every C
/// bitwise identical to a static coordinator throughout (the mid-stream
/// flip changes algo/artifact provenance, never the numbers).
#[test]
fn misroute_converges_with_exact_flip_index() {
    let tuning = TunerConfig {
        enabled: true,
        alpha: 0.5,       // exactly representable: mirror math is exact
        min_samples: 2,
        explore_every: 3,
        seed: 0x5EED_CAFE,
        register_refine_budget: 0,
    };
    let cfg = CoordinatorConfig { workers: 1, tuning, ..Default::default() };
    let clock = Arc::new(ScriptedClock::new(vec![]));
    let coord =
        Coordinator::with_clock(Arc::new(registry_no_csr()), cfg, Arc::<ScriptedClock>::clone(&clock));
    let static_coord = Coordinator::new(
        Arc::new(registry_no_csr()),
        CoordinatorConfig { workers: 1, ..Default::default() },
    );

    let mut rng = Rng::new(0x985);
    let a = gen::uniform(64, 0.985, &mut rng);
    let entry = coord.put_a(a.clone(), None).expect("put_a");
    assert_eq!(entry.plan.algo, Algo::Gcoo, "the prior misroutes this matrix to gcoo");
    let algos: Vec<Algo> = entry.candidates.iter().map(|c| c.algo).collect();
    assert_eq!(algos, vec![Algo::Gcoo, Algo::DenseXla], "no csr: one alternative");
    let key = ModelKey::operand(entry.handle);

    // Scripted latencies (exact powers of two): gcoo 0.5 s, dense 0.0625 s
    // per request — dense is 8× faster per the fake clock.
    const LAT_GCOO: f64 = 0.5;
    const LAT_DENSE: f64 = 0.0625;
    let mut mirror = Mirror { alpha: 0.5, min_samples: 2, est: HashMap::new() };
    let mut incumbent = Algo::Gcoo;
    let mut flip_at: Option<usize> = None;
    let mut explorations = 0u64;

    for i in 0..24usize {
        // Mirror the live routing decision for request i, then script its
        // latency pair before issuing it.
        let alt = if incumbent == Algo::Gcoo { Algo::DenseXla } else { Algo::Gcoo };
        let draw = explore_draw(tuning.seed, key, i as u64, tuning.explore_every);
        let predicted = if draw { alt } else { incumbent };
        if draw {
            explorations += 1;
        }
        let lat = if predicted == Algo::Gcoo { LAT_GCOO } else { LAT_DENSE };
        clock.push_latency(lat);

        let b = Mat::randn(64, 64, &mut rng);
        let mut req = SpdmRequest::for_handle(100 + i as u64, entry.handle, b.clone());
        req.verify = true;
        let resp = coord.run_sync(req);
        assert!(resp.ok(), "[{i}] {:?}", resp.error);
        assert_eq!(resp.verified, Some(true));
        assert_eq!(
            resp.algo, predicted,
            "[{i}] live routing diverged from the pure-function mirror"
        );

        // The static reference: same A, same B, static routing (gcoo).
        let sresp = static_coord.run_sync(SpdmRequest::new(500 + i as u64, a.clone(), b));
        assert_eq!(sresp.algo, Algo::Gcoo);
        assert!(
            resp.c == sresp.c,
            "[{i}] adaptive C (ran {:?}) must be bitwise identical to static gcoo",
            resp.algo
        );

        // Mirror the observation and the flip rule.
        mirror.observe(predicted, lat / 64.0);
        if let (Some(inc_m), Some(alt_m)) = (mirror.gated(incumbent), mirror.gated(alt)) {
            if alt_m < inc_m && flip_at.is_none() {
                flip_at = Some(i);
                incumbent = alt;
            }
        }
        let expected_flips = match flip_at {
            Some(f) if i >= f => 1,
            _ => 0,
        };
        assert_eq!(
            coord.snapshot().route_flips,
            expected_flips,
            "[{i}] flip counter must transition exactly at the mirrored index"
        );
    }

    // The convergence claim, pinned exactly.
    let flipped_at = flip_at.expect("dense-favoring latencies must force a flip within K=24");
    assert_eq!(incumbent, Algo::DenseXla);
    let snap = coord.snapshot();
    assert_eq!(snap.route_flips, 1, "exactly one flip, at request {flipped_at}");
    assert_eq!(snap.explorations, explorations, "every exploration was a scripted draw");
    // The store republished the entry: same handle, version 2, dense
    // incumbent, candidates reordered — and the old pinned version's Arc
    // (our `entry`) still reads the original gcoo routing.
    let republished = coord
        .store()
        .entries_snapshot()
        .into_iter()
        .find(|e| e.handle == entry.handle)
        .expect("still resident");
    assert_eq!(republished.version, 2);
    assert_eq!(republished.plan.algo, Algo::DenseXla);
    assert_eq!(republished.plan.reason, "measured-flip");
    assert_eq!(republished.candidates[0].algo, Algo::DenseXla);
    assert_eq!(entry.version, 1, "pre-flip snapshot untouched");
    assert_eq!(entry.plan.algo, Algo::Gcoo);
    // explain reflects the measured state.
    let doc = gcoospdm::json::parse(&coord.explain_json()).expect("explain is valid JSON");
    assert_eq!(doc.get("route_flips").unwrap().as_u64(), Some(1));
    let entries = doc.get("entries").unwrap().as_arr().unwrap();
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].get("version").unwrap().as_u64(), Some(2));
    assert_eq!(entries[0].get("algo").unwrap().as_str(), Some("dense_xla"));
    let ests = entries[0].get("estimates").unwrap().as_arr().unwrap();
    assert!(
        ests.iter().any(|e| e.get("algo").unwrap().as_str() == Some("gcoo")
            && e.get("gated").unwrap().as_bool() == Some(true)),
        "gcoo estimate is gated open"
    );

    coord.shutdown();
    static_coord.shutdown();
}

/// Satellite 4 (trace-replay determinism): replay one fixed-seed trace
/// through a live coordinator twice — fresh coordinator, fresh scripted
/// clock each time — and the two runs must produce identical per-item
/// resolved algorithms and identical (non-empty) flip schedules:
/// determinism end to end, from the trace generator through the tuner.
#[test]
fn trace_replay_same_seed_has_identical_flip_schedule() {
    fn run_once(trace_seed: u64) -> (Vec<u64>, Vec<(u64, Option<String>)>) {
        let tuning = TunerConfig {
            enabled: true,
            alpha: 0.5,
            min_samples: 2,
            explore_every: 3,
            seed: 0xAB5_0123,
            register_refine_budget: 0,
        };
        let cfg = CoordinatorConfig { workers: 1, tuning, ..Default::default() };
        let clock = Arc::new(ScriptedClock::new(vec![]));
        let coord = Arc::new(Coordinator::with_clock(
            Arc::new(registry_no_csr()),
            cfg,
            Arc::<ScriptedClock>::clone(&clock),
        ));
        let spec = TraceSpec {
            requests: 24,
            rate_rps: 1e9, // arrivals effectively immediate: no pacing sleeps
            sizes: vec![64],
            sparsities: vec![0.985],
            patterns: vec!["uniform".into()],
            seed: trace_seed,
            shared_a_pool: 1,
            shared_a_zipf: 1.0,
        };
        let pool = serve::shared_pool(&spec);
        let items = serve::generate_trace(&spec);
        let slot = &pool[0];
        let a = gen::generate(
            gen::Pattern::from_name(&slot.pattern).unwrap(),
            slot.n,
            slot.sparsity,
            &mut Rng::new(slot.seed),
        );

        // Lock-step driver state: the mirror predicts which algo each
        // request runs so the scripted clock can hand it the matching
        // latency (gcoo slow, dense fast — same scenario as the
        // convergence test), and flips are detected via the live counter.
        struct Driver {
            handle: Option<gcoospdm::coordinator::OperandId>,
            mirror: Mirror,
            incumbent: Algo,
            idx: u64,
            flips_seen: u64,
        }
        let state = Mutex::new(Driver {
            handle: None,
            mirror: Mirror { alpha: 0.5, min_samples: 2, est: HashMap::new() },
            incumbent: Algo::Gcoo,
            idx: 0,
            flips_seen: 0,
        });
        let tuning_seed = tuning.seed;
        let report = serve::replay_trace(&items, 1, |item| {
            let mut st = state.lock().unwrap();
            let (handle, kind) = match st.handle {
                Some(h) => (h, serve::ReplayKind::StoreHit),
                None => {
                    let entry = coord.put_a(a.clone(), None).map_err(|e| e.to_string())?;
                    st.handle = Some(entry.handle);
                    (entry.handle, serve::ReplayKind::StoreMiss)
                }
            };
            let key = ModelKey::operand(handle);
            let alt = if st.incumbent == Algo::Gcoo { Algo::DenseXla } else { Algo::Gcoo };
            let draw = explore_draw(tuning_seed, key, st.idx, 3);
            let predicted = if draw { alt } else { st.incumbent };
            let lat = if predicted == Algo::Gcoo { 0.5 } else { 0.0625 };
            clock.push_latency(lat);
            st.idx += 1;

            let b = Mat::randn(64, 64, &mut Rng::new(item.seed));
            let resp = coord.run_sync(SpdmRequest::for_handle(item.id, handle, b));
            if !resp.ok() {
                return Err(resp.error.unwrap_or_default());
            }
            st.mirror.observe(predicted, lat / 64.0);
            if let (Some(i), Some(a_m)) =
                (st.mirror.gated(st.incumbent), st.mirror.gated(alt))
            {
                if a_m < i {
                    st.incumbent = alt;
                }
            }
            let flips = coord.snapshot().route_flips;
            let flipped = flips > st.flips_seen;
            st.flips_seen = flips;
            let mut outcome = match kind {
                serve::ReplayKind::StoreHit => ReplayOutcome::store_hit(),
                _ => ReplayOutcome::store_miss(),
            };
            outcome = outcome.with_algo(resp.algo.as_str()).with_flip(flipped);
            Ok(outcome)
        });
        assert_eq!(report.failed, 0);
        assert_eq!(report.completed, 24);
        assert_eq!(report.store_misses, 1, "one registration for the single slot");
        let algos = report
            .outcomes
            .iter()
            .map(|(id, o)| (*id, o.algo.clone()))
            .collect();
        (report.flip_schedule(), algos)
    }

    let (flips1, algos1) = run_once(0x7ACE);
    let (flips2, algos2) = run_once(0x7ACE);
    assert!(!flips1.is_empty(), "the dense-favoring scenario must flip at least once");
    assert_eq!(flips1, flips2, "same seed ⇒ identical flip schedule");
    assert_eq!(algos1, algos2, "same seed ⇒ identical per-item resolved algos");
}

/// `explain` over the wire: the reply's `routing` field is a JSON routing
/// table (policy + per-entry candidates), served next to the v1/v2
/// traffic on the same connection.
#[test]
fn explain_round_trips_over_the_wire() {
    let coord = Arc::new(Coordinator::new(
        Arc::new(registry_full()),
        CoordinatorConfig { workers: 1, ..Default::default() },
    ));
    let server = Server::bind(&ServerConfig::ephemeral(), Arc::clone(&coord)).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        let _ = server.run();
    });
    let mut client = Client::connect(&addr).unwrap();

    let r = client.put_a_synthetic(1, 64, 0.99, "uniform", 5, "auto").unwrap();
    assert!(r.ok, "{:?}", r.error);
    let r = client.explain(2).unwrap();
    assert!(r.ok, "{:?}", r.error);
    let doc = gcoospdm::json::parse(r.routing.as_deref().expect("routing payload")).unwrap();
    let policy = doc.get("policy").unwrap();
    assert_eq!(policy.get("gcoo_crossover").unwrap().as_f64(), Some(0.98));
    assert_eq!(policy.get("tuning_enabled").unwrap().as_bool(), Some(false));
    assert_eq!(doc.get("route_flips").unwrap().as_u64(), Some(0));
    let entries = doc.get("entries").unwrap().as_arr().unwrap();
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].get("version").unwrap().as_u64(), Some(1));
    assert_eq!(entries[0].get("algo").unwrap().as_str(), Some("gcoo"));
    let cands = entries[0].get("candidates").unwrap().as_arr().unwrap();
    assert!(
        cands.len() >= 2,
        "unhinted registration publishes alternatives: {cands:?}"
    );
    assert_eq!(cands[0].get("algo").unwrap().as_str(), Some("gcoo"));

    client.shutdown(99).unwrap();
    handle.join().unwrap();
}
