//! Tenancy + spill differential (ISSUE 9 acceptance): admission control,
//! weighted-fair lanes, store slices, and the disk spill tier may change
//! *scheduling order and residency* — never *result bits*.
//!
//! * (a) a 3-tenant mixed workload (quotas + over-subscribed slices with
//!   spilling) produces bitwise-identical checksums to the same requests
//!   run untenanted and unspilled — on the JSON plane, the binary plane,
//!   and through a 3-node cluster whose router forwards tenant ids
//!   verbatim;
//! * (b) a hot tenant flooding `put_a` cannot evict another tenant's
//!   resident operands (slice isolation asserted on store gauges) and
//!   gets typed `RATE_LIMITED` / `QUOTA_EXCEEDED` errors — never a hang,
//!   a silent drop, or a closed connection;
//! * (c) a demoted-then-promoted handle serves with **zero**
//!   reconversions: `conversions_total` is constant across the
//!   demote/promote cycle;
//! * spill round-trip across the full pattern corpus: demote → promote
//!   yields a bitwise-identical `DeviceOperand` and bitwise-identical C.
//!
//! The scripted-clock DRR no-starvation property test lives next to the
//! lane implementation in `src/coordinator/queue.rs`; the token-bucket
//! unit tests next to the registry in `src/coordinator/tenant.rs`.

use std::path::PathBuf;
use std::sync::Arc;

use gcoospdm::coordinator::{
    Coordinator, CoordinatorConfig, SpdmRequest, TenantSpec, QUOTA_EXCEEDED, RATE_LIMITED,
};
use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::{DeviceOperand, Registry};
use gcoospdm::serve::{Client, Cluster, ClusterConfig, Server, ServerConfig};

/// Stub registry at n=64 (distinct target dir so parallel test binaries
/// never race on the files).
fn runnable_registry() -> Registry {
    let dir = PathBuf::from("target/tenant_differential_artifacts");
    std::fs::create_dir_all(&dir).expect("create stub artifact dir");
    std::fs::write(dir.join("stub.hlo.txt"), b"stub").expect("write stub artifact");
    let manifest = r#"{"artifacts": [
        {"name": "gcoo_n64_cap64", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "gcoo_n64_cap512", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "csr_n64_rowcap64", "algo": "csr", "n": 64,
         "params": {"rp": 8, "rowcap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "dense_xla_n64", "algo": "dense_xla", "n": 64,
         "params": {}, "inputs": [], "file": "stub.hlo.txt"}
    ]}"#;
    Registry::from_manifest_json(manifest, dir).expect("stub manifest parses")
}

fn boot(cfg: CoordinatorConfig) -> (Arc<Coordinator>, String, std::thread::JoinHandle<()>) {
    let coord = Arc::new(Coordinator::new(Arc::new(runnable_registry()), cfg));
    let server = Server::bind(&ServerConfig::ephemeral(), Arc::clone(&coord)).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        let _ = server.run();
    });
    (coord, addr, handle)
}

fn tmp_dir(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gcoospdm_tenantdiff_{}_{name}", std::process::id()))
}

fn spec(name: &str, weight: u32, rate: f64, burst: f64, slice: u64) -> TenantSpec {
    TenantSpec { name: name.to_string(), weight, rate_per_s: rate, burst, store_slice_bytes: slice }
}

const N: usize = 64;
const TENANTS: [&str; 3] = ["alpha", "beta", "gamma"];

/// Deterministic 3-tenant workload: two registered operands per tenant
/// (each multiplied by its own B) plus one inline pair per tenant.
fn make_work() -> (Vec<Vec<(Mat, Mat)>>, Vec<(Mat, Mat)>) {
    let mut per = Vec::new();
    for ti in 0..TENANTS.len() as u64 {
        let mut ops = Vec::new();
        for k in 0..2u64 {
            let mut rng = Rng::new(100 + ti * 10 + k);
            let a = gen::generate(gen::Pattern::Uniform, N, 0.9, &mut rng);
            let b = Mat::randn(N, N, &mut rng);
            ops.push((a, b));
        }
        per.push(ops);
    }
    let inline = (0..TENANTS.len() as u64)
        .map(|ti| {
            let mut rng = Rng::new(900 + ti);
            let a = gen::generate(gen::Pattern::Uniform, N, 0.9, &mut rng);
            let b = Mat::randn(N, N, &mut rng);
            (a, b)
        })
        .collect();
    (per, inline)
}

/// A store-slice size that fits any single workload operand but never two
/// of one tenant's — measured, not guessed, so routing/cap choices can't
/// silently defeat the over-subscription the test depends on.
fn measure_slice(per: &[Vec<(Mat, Mat)>]) -> u64 {
    let coord =
        Coordinator::new(Arc::new(runnable_registry()), CoordinatorConfig { workers: 1, ..Default::default() });
    let mut max_one = 0u64;
    let mut min_sum = u64::MAX;
    for ops in per {
        let mut sum = 0u64;
        for (a, _) in ops {
            let e = coord.put_a(a.clone(), None).unwrap();
            max_one = max_one.max(e.bytes);
            sum += e.bytes;
        }
        min_sum = min_sum.min(sum);
    }
    coord.shutdown();
    assert!(
        max_one < min_sum,
        "workload must admit a slice fitting one operand but not two ({max_one} vs {min_sum})"
    );
    (max_one + min_sum) / 2
}

/// Run the workload through one client, optionally tagging each request
/// with its tenant, and return every checksum's bits in request order.
/// Revisiting operand 0 after operand 1 displaced it (and vice versa) is
/// what forces demote → promote cycles in the over-subscribed config.
fn run_workload(
    client: &mut Client,
    tag: bool,
    per: &[Vec<(Mat, Mat)>],
    inline: &[(Mat, Mat)],
    id_base: u64,
) -> Vec<u64> {
    let mut sums = Vec::new();
    let mut id = id_base;
    for (ti, tenant) in TENANTS.iter().enumerate() {
        client.set_tenant(if tag { Some(*tenant) } else { None });
        let mut handles = Vec::new();
        for (a, b) in &per[ti] {
            let r = client.put_a_inline(id, N, &a.data, "auto").unwrap();
            assert!(r.ok, "put_a for {tenant}: {:?}", r.error);
            let h = r.a_handle.unwrap();
            let r = client.spdm_handle(id + 1, h, &b.data, false).unwrap();
            assert!(r.ok, "spdm_handle for {tenant}: {:?}", r.error);
            sums.push(r.checksum.unwrap().to_bits());
            handles.push(h);
            id += 2;
        }
        // Revisit both operands on the binary plane: in the sliced config
        // each revisit promotes a spilled entry (displacing the other).
        for (k, h) in handles.iter().enumerate() {
            let b = &per[ti][k].1;
            let (r, _) = client.spdm_handle_bin(id, *h, N, &b.data, None, false, false).unwrap();
            assert!(r.ok, "revisit a#{h} for {tenant}: {:?}", r.error);
            sums.push(r.checksum.unwrap().to_bits());
            id += 1;
        }
        // One inline request per tenant on each plane.
        let (a, b) = &inline[ti];
        let (r, _) = client.spdm_inline_bin(id, N, &a.data, &b.data, None, false, false).unwrap();
        assert!(r.ok, "inline bin for {tenant}: {:?}", r.error);
        sums.push(r.checksum.unwrap().to_bits());
        let r = client.spdm_inline(id + 1, N, &a.data, &b.data, false).unwrap();
        assert!(r.ok, "inline json for {tenant}: {:?}", r.error);
        sums.push(r.checksum.unwrap().to_bits());
        id += 2;
    }
    sums
}

fn tenanted_cfg(slice: u64, spill_dir: PathBuf) -> CoordinatorConfig {
    CoordinatorConfig {
        workers: 1,
        tenants: vec![
            spec("alpha", 1, 0.0, 0.0, slice),
            spec("beta", 2, 0.0, 0.0, slice),
            spec("gamma", 4, 0.0, 0.0, slice),
        ],
        spill_dir: Some(spill_dir),
        ..Default::default()
    }
}

/// Acceptance (a), single node: the tenanted, slice-over-subscribed,
/// spilling deployment answers bitwise identically to the untenanted,
/// unspilled one — on both wire planes.
#[test]
fn tenanted_spilling_workload_bitwise_matches_untenanted_on_both_planes() {
    let (per, inline) = make_work();
    let slice = measure_slice(&per);

    // Baseline: untenanted, ample budget, no spill tier.
    let (_c0, addr0, s0) = boot(CoordinatorConfig { workers: 1, ..Default::default() });
    let mut base = Client::connect(&addr0).unwrap();
    let baseline = run_workload(&mut base, false, &per, &inline, 1_000);
    base.shutdown(9_998).unwrap();
    s0.join().unwrap();

    // Tenanted: per-tenant slices force demote/promote churn.
    let dir = tmp_dir("planes");
    let (c1, addr1, s1) = boot(tenanted_cfg(slice, dir.clone()));
    let mut tcl = Client::connect(&addr1).unwrap();
    let tenanted = run_workload(&mut tcl, true, &per, &inline, 1_000);
    assert_eq!(baseline, tenanted, "tenancy + spilling must never change result bits");

    // The over-subscription actually happened: every tenant demoted at
    // least once and every revisit promoted from disk.
    let snap = c1.snapshot();
    assert!(snap.spill_writes >= 3, "expected spill writes, got {}", snap.spill_writes);
    assert!(snap.spill_promotes >= 3, "expected spill promotes, got {}", snap.spill_promotes);

    tcl.shutdown(9_999).unwrap();
    s1.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance (a), cluster: the same tenanted workload through a 3-node
/// cluster (router forwards tenant ids verbatim on both planes) is
/// bitwise identical to the untenanted single-node baseline.
#[test]
fn tenanted_workload_through_three_node_cluster_bitwise_matches_single_node() {
    let (per, inline) = make_work();
    let slice = measure_slice(&per);

    let (_c0, addr0, s0) = boot(CoordinatorConfig { workers: 1, ..Default::default() });
    let mut base = Client::connect(&addr0).unwrap();
    let baseline = run_workload(&mut base, false, &per, &inline, 3_000);
    base.shutdown(9_998).unwrap();
    s0.join().unwrap();

    let dir = tmp_dir("cluster");
    let ccfg = ClusterConfig {
        nodes: 3,
        replicate_after: 10_000, // keep replication out of this differential
        node_cfg: tenanted_cfg(slice, dir.clone()),
        ..ClusterConfig::default()
    };
    let mut cluster = Cluster::start(&ccfg, Arc::new(runnable_registry())).unwrap();
    let mut tcl = Client::connect(cluster.router_addr()).unwrap();
    let clustered = run_workload(&mut tcl, true, &per, &inline, 3_000);
    assert_eq!(baseline, clustered, "a tenanted cluster answers bitwise like a single node");

    // Tenant rows merge across nodes: every registered operand appears
    // exactly once in cluster list_a, with tier and recency columns.
    tcl.set_tenant(None);
    let r = tcl.list_a(8_000).unwrap();
    assert!(r.ok);
    let rows = r.handles.unwrap();
    assert_eq!(rows.len(), 6, "six registered operands across the cluster");
    for row in &rows {
        assert!(row.tier == "ram" || row.tier == "spilled", "tier column: {}", row.tier);
        assert!(row.bytes > 0);
    }

    cluster.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance (b): a hot tenant flooding `put_a` churns only its own
/// slice; the victim's operand stays resident (gauge-asserted), quota and
/// rate rejections are typed errors, and the connection always survives.
#[test]
fn hot_tenant_flood_cannot_evict_victim_and_gets_typed_backpressure() {
    let (per, _) = make_work();
    let slice = measure_slice(&per);

    let dir = tmp_dir("flood");
    let cfg = CoordinatorConfig {
        workers: 1,
        tenants: vec![
            spec("hog", 1, 0.0, 0.0, slice),
            spec("victim", 1, 0.0, 0.0, slice),
            // Slice smaller than any operand: every registration is over
            // quota.
            spec("tiny", 1, 0.0, 0.0, 1024),
            // Burst of one token, refill slow enough to be negligible for
            // the test's lifetime: request #2 is deterministically limited.
            spec("ratey", 1, 1e-6, 1.0, 0),
        ],
        spill_dir: Some(dir.clone()),
        ..Default::default()
    };
    let (coord, addr, server) = boot(cfg);
    let mut client = Client::connect(&addr).unwrap();

    // Victim registers one operand and keeps it resident.
    client.set_tenant(Some("victim"));
    let (va, vb) = &per[0][0];
    let r = client.put_a_inline(1, N, &va.data, "auto").unwrap();
    assert!(r.ok, "{:?}", r.error);
    let vh = r.a_handle.unwrap();
    let victim_bytes = coord.store().tenant_bytes_of("victim");
    assert!(victim_bytes > 0);

    // Hog floods distinct operands; its slice holds one at a time, so
    // every extra registration demotes its own previous entry — never
    // the victim's.
    client.set_tenant(Some("hog"));
    let mut id = 10u64;
    for seed in 0..4u64 {
        let mut rng = Rng::new(7_000 + seed);
        let a = gen::generate(gen::Pattern::Uniform, N, 0.9, &mut rng);
        let r = client.put_a_inline(id, N, &a.data, "auto").unwrap();
        assert!(r.ok, "hog put_a #{seed}: {:?}", r.error);
        id += 1;
    }
    let st = coord.store().stats();
    assert!(st.spill_writes >= 3, "hog churn demotes its own entries: {}", st.spill_writes);
    assert_eq!(
        coord.store().tenant_bytes_of("victim"),
        victim_bytes,
        "slice isolation: hog pressure never touches the victim's resident bytes"
    );
    assert!(
        coord.store().peek_entry(gcoospdm::coordinator::OperandId(vh)).is_some(),
        "victim operand stays RAM-resident through the flood"
    );

    // And the victim still serves from cache.
    client.set_tenant(Some("victim"));
    let r = client.spdm_handle(100, vh, &vb.data, false).unwrap();
    assert!(r.ok, "{:?}", r.error);

    // QUOTA_EXCEEDED: a tenant whose slice can't fit the operand gets a
    // typed error; the connection survives.
    client.set_tenant(Some("tiny"));
    let r = client.put_a_inline(200, N, &va.data, "auto").unwrap();
    assert!(!r.ok, "over-quota put_a must be rejected");
    let err = r.error.unwrap();
    assert!(err.contains(QUOTA_EXCEEDED), "typed quota error: {err}");
    assert!(client.ping(201).unwrap().ok, "connection survives QUOTA_EXCEEDED");

    // RATE_LIMITED on both planes: token #1 admits, #2 is rejected with
    // the typed error — never a hang or a silent drop — and the same
    // socket keeps serving.
    client.set_tenant(Some("ratey"));
    let (ia, ib) = &per[1][0];
    let r = client.spdm_inline(300, N, &ia.data, &ib.data, false).unwrap();
    assert!(r.ok, "first ratey request rides the burst: {:?}", r.error);
    let r = client.spdm_inline(301, N, &ia.data, &ib.data, false).unwrap();
    assert!(!r.ok, "second ratey request must be limited");
    let err = r.error.unwrap();
    assert!(err.contains(RATE_LIMITED), "typed rate error: {err}");
    let (r, _) = client.spdm_inline_bin(302, N, &ia.data, &ib.data, None, false, false).unwrap();
    assert!(!r.ok, "binary plane is limited identically");
    assert!(r.error.unwrap().contains(RATE_LIMITED));
    let r = client.put_a_inline(303, N, &ia.data, "auto").unwrap();
    assert!(!r.ok, "put_a shares the tenant's bucket");
    assert!(r.error.unwrap().contains(RATE_LIMITED));
    assert!(client.ping_bin(304).unwrap().ok, "connection survives RATE_LIMITED");

    // Satellite (ISSUE 10): /stats is no longer tenant-blind — the
    // snapshot carries one row per configured lane with byte usage, the
    // slice budget, and the split rejection counters.
    let snap = coord.snapshot();
    let names: Vec<&str> = snap.tenants.iter().map(|t| t.name.as_str()).collect();
    assert_eq!(
        names,
        vec!["default", "hog", "ratey", "tiny", "victim"],
        "one row per configured lane, sorted by name"
    );
    let row = |n: &str| snap.tenants.iter().find(|t| t.name == n).unwrap();
    assert_eq!(row("victim").bytes, victim_bytes, "victim's resident bytes surface per-tenant");
    assert_eq!(row("victim").slice_budget_bytes, slice);
    assert!(row("hog").bytes > 0, "hog keeps its newest operand resident");
    assert_eq!(
        (row("tiny").quota_exceeded, row("tiny").rate_limited),
        (1, 0),
        "tiny's one over-quota put_a is counted against tiny alone"
    );
    assert_eq!(
        (row("ratey").rate_limited, row("ratey").quota_exceeded),
        (3, 0),
        "ratey's three limited requests (both planes + put_a) count against ratey alone"
    );
    for n in ["default", "hog", "victim"] {
        assert_eq!((row(n).rate_limited, row(n).quota_exceeded), (0, 0), "{n} saw no rejections");
    }

    client.shutdown(9_999).unwrap();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance (c): a demote/promote cycle performs **zero** reconversions
/// — the spilled device form is the one registration built, and
/// `conversions_total` stays constant while the promoted handle serves.
#[test]
fn demote_promote_cycle_never_reconverts() {
    let (per, _) = make_work();
    let slice = measure_slice(&per);
    let (a1, b1) = per[0][0].clone();
    let (a2, _) = per[0][1].clone();

    // Untenanted baseline C for the same request.
    let base = Coordinator::new(
        Arc::new(runnable_registry()),
        CoordinatorConfig { workers: 1, ..Default::default() },
    );
    let be = base.put_a(a1.clone(), None).unwrap();
    let bresp = base.run_sync(SpdmRequest::for_handle(1, be.handle, b1.clone()));
    assert!(bresp.error.is_none(), "{:?}", bresp.error);
    let base_c = bresp.c.expect("baseline C");
    base.shutdown();

    let dir = tmp_dir("noreconvert");
    let cfg = CoordinatorConfig {
        workers: 1,
        tenants: vec![spec("solo", 1, 0.0, 0.0, slice)],
        spill_dir: Some(dir.clone()),
        ..Default::default()
    };
    let coord = Coordinator::new(Arc::new(runnable_registry()), cfg);
    let e1 = coord.put_a_for("solo", a1, None).unwrap();
    let h1 = e1.handle;
    let _e2 = coord.put_a_for("solo", a2, None).unwrap();
    let converted = coord.snapshot().conversions_total;
    assert_eq!(converted, 2, "both registrations converted once");
    let st = coord.store().stats();
    assert!(st.spill_writes >= 1, "registration #2 demoted #1");
    let spilled_row = coord.list_a().into_iter().find(|s| s.handle == h1).unwrap();
    assert_eq!(spilled_row.tier, "spilled", "h1 lives in the disk tier");

    // Serve the spilled handle: promoted, verified, executed — and the
    // conversion counter does not move.
    let resp = coord.run_sync(SpdmRequest::for_handle(2, h1, b1).with_tenant("solo"));
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.conversions, 0, "a promoted handle pays no conversion");
    assert_eq!(
        coord.snapshot().conversions_total,
        converted,
        "conversions_total is constant across the demote/promote cycle"
    );
    assert_eq!(coord.store().stats().spill_promotes, 1);
    let ram_row = coord.list_a().into_iter().find(|s| s.handle == h1).unwrap();
    assert_eq!(ram_row.tier, "ram", "promotion restored RAM residency");

    let c = resp.c.expect("tenanted C");
    assert_eq!(c.rows, base_c.rows);
    for (got, want) in c.data.iter().zip(base_c.data.iter()) {
        assert_eq!(got.to_bits(), want.to_bits(), "promoted C is bitwise the baseline C");
    }
    coord.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

fn count_spill_files(dir: &std::path::Path) -> usize {
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.flatten()
                .filter(|e| e.path().extension().is_some_and(|x| x == "spill"))
                .count()
        })
        .unwrap_or(0)
}

/// ISSUE 10 spill-leak bugfix: the in-memory index is authoritative and
/// the files follow it. Counting on-disk `.spill` files across
/// demote → drop_a → shutdown → restart never finds an orphan:
/// `drop_a` deletes the demoted file, shutdown sweeps the tier (and
/// removes the emptied directory), and a restart's startup GC clears
/// any orphan a crash left behind.
#[test]
fn spill_tier_leaves_zero_files_after_drop_a_shutdown_and_restart() {
    let (per, _) = make_work();
    let slice = measure_slice(&per);
    let (a1, _) = per[0][0].clone();
    let (a2, b2) = per[0][1].clone();
    let (a3, _) = per[1][0].clone();

    let dir = tmp_dir("leak");
    let cfg = || CoordinatorConfig {
        workers: 1,
        tenants: vec![spec("solo", 1, 0.0, 0.0, slice)],
        spill_dir: Some(dir.clone()),
        ..Default::default()
    };
    let coord = Coordinator::new(Arc::new(runnable_registry()), cfg());
    assert_eq!(count_spill_files(&dir), 0, "fresh tier starts empty");

    // Registration #2 demotes #1: exactly one file on disk.
    let e1 = coord.put_a_for("solo", a1, None).unwrap();
    let _e2 = coord.put_a_for("solo", a2, None).unwrap();
    assert!(coord.store().stats().spill_writes >= 1, "over-subscription demotes");
    assert_eq!(count_spill_files(&dir), 1, "one demoted entry, one file");

    // drop_a of the demoted handle deletes its file, not just the index row.
    assert!(coord.drop_a(e1.handle), "drop_a finds the spilled handle");
    assert_eq!(count_spill_files(&dir), 0, "drop_a must delete the spill file");

    // Leave a fresh demoted file behind, then shut down: the sweep clears
    // the tier and removes the emptied directory.
    let e3 = coord.put_a_for("solo", a3, None).unwrap();
    assert_eq!(count_spill_files(&dir), 1, "registration #3 demoted #2");
    coord.shutdown();
    assert_eq!(count_spill_files(&dir), 0, "shutdown sweeps every spill file");
    assert!(!dir.exists(), "the emptied spill directory is removed too");
    let _ = e3;

    // Restart on the same directory with a crash-orphaned file planted:
    // startup GC deletes it before the tier serves anything.
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("a424242.spill"), b"stale bytes from a crashed run").unwrap();
    let coord = Coordinator::new(Arc::new(runnable_registry()), cfg());
    assert_eq!(count_spill_files(&dir), 0, "restart GCs crash orphans");
    // The restarted tier still works: registrations demote and serve.
    let e1 = coord.put_a_for("solo", per[0][0].0.clone(), None).unwrap();
    let _e2 = coord.put_a_for("solo", per[0][1].0.clone(), None).unwrap();
    let resp = coord.run_sync(SpdmRequest::for_handle(7, e1.handle, b2).with_tenant("solo"));
    assert!(resp.error.is_none(), "{:?}", resp.error);
    coord.shutdown();
    assert_eq!(count_spill_files(&dir), 0, "second shutdown leaves zero files");
    let _ = std::fs::remove_dir_all(&dir);
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn operand_bitwise_eq(x: &DeviceOperand, y: &DeviceOperand) -> bool {
    match (x, y) {
        (DeviceOperand::Gcoo(a), DeviceOperand::Gcoo(b)) => {
            (a.g, a.cap, a.p, a.n) == (b.g, b.cap, b.p, b.n)
                && bits(&a.vals) == bits(&b.vals)
                && a.rows == b.rows
                && a.cols == b.cols
        }
        (DeviceOperand::Ell(a), DeviceOperand::Ell(b)) => {
            (a.n, a.rowcap) == (b.n, b.rowcap) && bits(&a.vals) == bits(&b.vals) && a.cols == b.cols
        }
        (DeviceOperand::Dense(a), DeviceOperand::Dense(b)) => {
            (a.rows, a.cols) == (b.rows, b.cols) && bits(&a.data) == bits(&b.data)
        }
        (DeviceOperand::Cmrs(a), DeviceOperand::Cmrs(b)) => {
            (a.g, a.cap, a.p, a.n) == (b.g, b.cap, b.p, b.n)
                && bits(&a.vals) == bits(&b.vals)
                && a.rows == b.rows
                && a.cols == b.cols
        }
        (DeviceOperand::RowSplit(a), DeviceOperand::RowSplit(b)) => {
            (a.segs, a.cap, a.n) == (b.segs, b.cap, b.n)
                && bits(&a.vals) == bits(&b.vals)
                && a.seg_rows == b.seg_rows
                && a.cols == b.cols
        }
        _ => false,
    }
}

/// Satellite: across **the full corpus** (all 9 patterns, adversarial
/// families included), demote → promote restores
/// a bitwise-identical `DeviceOperand` and serves a bitwise-identical C.
#[test]
fn spill_round_trip_is_bitwise_across_all_corpus_patterns() {
    let registry = Arc::new(runnable_registry());
    for (pi, pat) in gen::Pattern::ALL.iter().enumerate() {
        let mut rng = Rng::new(4_000 + pi as u64);
        let a = gen::generate(*pat, N, 0.9, &mut rng);
        let b = Mat::randn(N, N, &mut rng);
        let mut rng2 = Rng::new(5_000 + pi as u64);
        let filler = gen::generate(gen::Pattern::Uniform, N, 0.9, &mut rng2);

        // Measure this pattern's pair so the slice fits either operand
        // alone but not both.
        let meter = Coordinator::new(
            Arc::clone(&registry),
            CoordinatorConfig { workers: 1, ..Default::default() },
        );
        let ea = meter.put_a(a.clone(), None).unwrap();
        let ef = meter.put_a(filler.clone(), None).unwrap();
        let slice = (ea.bytes.max(ef.bytes) + ea.bytes + ef.bytes) / 2;
        let bresp = meter.run_sync(SpdmRequest::for_handle(1, ea.handle, b.clone()));
        assert!(bresp.error.is_none(), "{}: {:?}", pat.name(), bresp.error);
        let base_c = bresp.c.expect("baseline C");
        meter.shutdown();

        let dir = tmp_dir(pat.name());
        let cfg = CoordinatorConfig {
            workers: 1,
            tenants: vec![spec("solo", 1, 0.0, 0.0, slice)],
            spill_dir: Some(dir.clone()),
            ..Default::default()
        };
        let coord = Coordinator::new(Arc::clone(&registry), cfg);
        let e1 = coord.put_a_for("solo", a, None).unwrap();
        let h = e1.handle;
        let _e2 = coord.put_a_for("solo", filler, None).unwrap();
        assert!(
            coord.store().stats().spill_writes >= 1,
            "{}: filler registration demotes the pattern operand",
            pat.name()
        );

        // Promote via checkout and compare the restored entry bit-for-bit
        // against the pre-demotion entry we still hold.
        let pin = coord.store().checkout(h).expect("spilled handle promotes on checkout");
        let restored = pin.entry();
        assert_eq!(restored.sig, e1.sig, "{}: signature survives", pat.name());
        assert_eq!(bits(&restored.a.data), bits(&e1.a.data), "{}: dense A bits", pat.name());
        assert!(
            operand_bitwise_eq(&restored.operand, &e1.operand),
            "{}: device operand must round-trip bitwise",
            pat.name()
        );
        assert_eq!(restored.plan, e1.plan, "{}: plan survives", pat.name());
        drop(pin);

        let resp = coord.run_sync(SpdmRequest::for_handle(2, h, b).with_tenant("solo"));
        assert!(resp.error.is_none(), "{}: {:?}", pat.name(), resp.error);
        assert_eq!(resp.conversions, 0, "{}: no reconversion", pat.name());
        let c = resp.c.expect("promoted C");
        for (i, (got, want)) in c.data.iter().zip(base_c.data.iter()).enumerate() {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "{}: C[{i}] must be bitwise identical after the spill round trip",
                pat.name()
            );
        }
        coord.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Weighted lanes are work-conserving and starvation-free end-to-end: an
/// 8:1 weight split still completes every light-tenant request.
#[test]
fn weighted_lanes_serve_every_tenant() {
    let cfg = CoordinatorConfig {
        workers: 1,
        tenants: vec![spec("heavy", 8, 0.0, 0.0, 0), spec("light", 1, 0.0, 0.0, 0)],
        ..Default::default()
    };
    let coord = Coordinator::new(Arc::new(runnable_registry()), cfg);
    let mut rxs = Vec::new();
    for i in 0..16u64 {
        let tenant = if i % 2 == 0 { "heavy" } else { "light" };
        let mut rng = Rng::new(6_000 + i);
        let a = gen::generate(gen::Pattern::Uniform, N, 0.9, &mut rng);
        let b = Mat::randn(N, N, &mut rng);
        rxs.push(coord.submit(SpdmRequest::new(i, a, b).with_tenant(tenant)).unwrap());
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("every submitted request completes");
        assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
    }
    coord.shutdown();
}
