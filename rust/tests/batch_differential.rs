//! Differential lockdown of fused multi-B batch execution: for every
//! corpus pattern and both sparse algorithms, executing a shape-affine
//! batch fused (one A conversion, one wide kernel, column scatter) must be
//! **bitwise identical** to executing the same requests sequentially
//! through `process_one_ws` — at widths 1, 2, 5 and `batch_max`, including
//! the ragged last batch — and a batch of k same-A requests must perform
//! exactly one A conversion.
//!
//! Runnable without `make artifacts`: like `zero_copy.rs`, the engine only
//! needs artifact *files to exist*, so a stub registry under `target/`
//! suffices.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use gcoospdm::coordinator::{
    process_batch_ws, process_one_ws, Algo, BatchJob, Coordinator, CoordinatorConfig,
    SpdmRequest, SpdmResponse, Workspace,
};
use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::{Engine, Registry};
use gcoospdm::simgpu::TraceRecorder;
use gcoospdm::sparse::Gcoo;

/// Stub registry at n=64: two gcoo capacities (so some workloads borrow at
/// cap 64 and others re-pad via cap 512), a csr variant wide enough for any
/// 64-row matrix, and the dense fallback.
fn runnable_registry() -> Registry {
    let dir = PathBuf::from("target/batch_differential_artifacts");
    std::fs::create_dir_all(&dir).expect("create stub artifact dir");
    std::fs::write(dir.join("stub.hlo.txt"), b"stub").expect("write stub artifact");
    let manifest = r#"{"artifacts": [
        {"name": "gcoo_n64_cap64", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "gcoo_n64_cap512", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "csr_n64_rowcap64", "algo": "csr", "n": 64,
         "params": {"rp": 8, "rowcap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "dense_xla_n64", "algo": "dense_xla", "n": 64,
         "params": {}, "inputs": [], "file": "stub.hlo.txt"}
    ]}"#;
    Registry::from_manifest_json(manifest, dir).expect("stub manifest parses")
}

/// k requests sharing one A (clones → equal signatures), distinct Bs.
fn same_a_requests(a: &Mat, k: usize, algo: Option<Algo>, rng: &mut Rng) -> Vec<SpdmRequest> {
    (0..k)
        .map(|i| {
            let mut req =
                SpdmRequest::new(i as u64, a.clone(), Mat::randn(a.rows, a.rows, rng));
            req.algo_hint = algo;
            // One oracle check per workload keeps the suite fast while still
            // pinning both paths to the true product.
            req.verify = i == 0;
            req
        })
        .collect()
}

fn run_sequential(
    engine: &Engine,
    reg: &Registry,
    cfg: &CoordinatorConfig,
    reqs: &[SpdmRequest],
) -> Vec<SpdmResponse> {
    let mut ws = Workspace::new();
    reqs.iter()
        .map(|r| process_one_ws(engine, &mut ws, reg, cfg, r, None, Instant::now()))
        .collect()
}

/// Chunk `reqs` into batches of `width` (last one ragged) and execute each
/// fused. Asserts the one-conversion invariant on every multi-job batch:
/// exactly the first job bills a conversion, the rest ride it for free.
fn run_batched(
    engine: &Engine,
    reg: &Registry,
    cfg: &CoordinatorConfig,
    reqs: &[SpdmRequest],
    width: usize,
) -> Vec<SpdmResponse> {
    let mut ws = Workspace::new();
    let mut out = Vec::with_capacity(reqs.len());
    for chunk in reqs.chunks(width) {
        let jobs: Vec<BatchJob<'_>> =
            chunk.iter().map(|r| BatchJob::inline(r, Instant::now())).collect();
        let resps = process_batch_ws(engine, &mut ws, reg, cfg, &jobs);
        assert_eq!(resps.len(), chunk.len());
        // Dense requests convert nothing, so the conversion-count invariant
        // is only observable on the sparse paths.
        if chunk.len() > 1 && resps.iter().all(|r| r.ok()) && resps[0].algo.is_sparse() {
            assert!(
                resps[0].convert_s > 0.0,
                "the batch's one conversion is billed to its first job"
            );
            assert!(
                resps[1..].iter().all(|r| r.convert_s == 0.0),
                "a fused batch must convert A exactly once"
            );
        }
        out.extend(resps);
    }
    out
}

fn assert_identical(seq: &[SpdmResponse], bat: &[SpdmResponse], ctx: &str) {
    assert_eq!(seq.len(), bat.len(), "{ctx}: response counts");
    for (i, (s, b)) in seq.iter().zip(bat).enumerate() {
        assert!(s.ok(), "{ctx}[{i}] sequential failed: {:?}", s.error);
        assert!(b.ok(), "{ctx}[{i}] batched failed: {:?}", b.error);
        assert_eq!(s.id, b.id, "{ctx}[{i}] id");
        assert_eq!(s.algo, b.algo, "{ctx}[{i}] algo");
        assert_eq!(s.n_exec, b.n_exec, "{ctx}[{i}] n_exec");
        assert_eq!(s.verified, b.verified, "{ctx}[{i}] verification verdicts");
        assert!(
            s.c == b.c,
            "{ctx}[{i}]: batched C is not bitwise identical to sequential C"
        );
        if i == 0 {
            assert_eq!(s.verified, Some(true), "{ctx}: oracle check on the first request");
        }
    }
}

/// The core differential: every corpus pattern × both sparse algorithms ×
/// widths {1, 2, 5, batch_max}, with matching (n=64) and padded (n=60)
/// request sizes, ragged final batches included.
#[test]
fn batched_execution_is_bitwise_identical_to_sequential() {
    let reg = runnable_registry();
    let engine = Engine::new().unwrap();
    let cfg = CoordinatorConfig::default();
    let widths = [1usize, 2, 5, cfg.batch_max];
    let mut rng = Rng::new(0xBA7C);
    for (pi, pattern) in gen::Pattern::ALL.iter().enumerate() {
        // Alternate matching and padded-up execution sizes so stacking is
        // exercised both at n == n_exec and across the pad border.
        let n = if pi % 2 == 0 { 64 } else { 60 };
        let a = gen::generate(*pattern, n, 0.95, &mut rng);
        for algo in [Algo::Gcoo, Algo::Csr] {
            for &w in &widths {
                // 2 full batches plus a ragged remainder (for w >= 2).
                let count = 2 * w + (w + 1) / 2;
                let reqs = same_a_requests(&a, count, Some(algo), &mut rng);
                let seq = run_sequential(&engine, &reg, &cfg, &reqs);
                let bat = run_batched(&engine, &reg, &cfg, &reqs, w);
                let ctx = format!("{}/{}/w{w}/n{n}", pattern.name(), algo.as_str());
                assert_identical(&seq, &bat, &ctx);
            }
        }
    }
}

/// The dense fallback also fuses correctly (stacked wide GEMM).
#[test]
fn batched_dense_matches_sequential() {
    let reg = runnable_registry();
    let engine = Engine::new().unwrap();
    let cfg = CoordinatorConfig::default();
    let mut rng = Rng::new(0xDE45);
    for n in [64usize, 60] {
        let a = gen::uniform(n, 0.4, &mut rng); // below crossover → dense
        let reqs = same_a_requests(&a, 5, None, &mut rng);
        let seq = run_sequential(&engine, &reg, &cfg, &reqs);
        assert!(seq.iter().all(|r| r.algo == Algo::DenseXla));
        let bat = run_batched(&engine, &reg, &cfg, &reqs, 5);
        for (i, (s, b)) in seq.iter().zip(&bat).enumerate() {
            assert!(s.ok() && b.ok(), "dense[{i}]: {:?} / {:?}", s.error, b.error);
            assert!(s.c == b.c, "dense[{i}] not bitwise identical (n={n})");
        }
    }
}

/// Exactly one slab borrow per fused batch: with a matching-capacity
/// artifact, the sequential path borrows once per request while the fused
/// path borrows once per batch — direct `CopyStats` evidence that the
/// batch ran one conversion + one kernel.
#[test]
fn fused_batch_borrows_slabs_once() {
    let reg = runnable_registry();
    let engine = Engine::new().unwrap();
    let cfg = CoordinatorConfig::default();
    let mut rng = Rng::new(0x51AB);
    // Sparsity 0.97 keeps every band under the cap=64 artifact.
    let a = gen::uniform(64, 0.97, &mut rng);
    let reqs = same_a_requests(&a, 6, Some(Algo::Gcoo), &mut rng);
    let seq = run_sequential(&engine, &reg, &cfg, &reqs);
    let seq_avoided: u64 = seq.iter().map(|r| r.copies_avoided).sum();
    assert!(
        seq_avoided >= 3 * reqs.len() as u64,
        "sequential: B borrow + slab borrow + C move per request"
    );
    let bat = run_batched(&engine, &reg, &cfg, &reqs, 6);
    let bat_avoided: u64 = bat.iter().map(|r| r.copies_avoided).sum();
    assert_eq!(
        bat_avoided, 1,
        "fused batch: one kernel invocation, one matching-cap slab borrow"
    );
    assert_identical(&seq, &bat, "copystats");
}

/// TraceSink contract on the fused wide-B kernel: tracing a width-3 batch
/// must not perturb the wide product (bitwise), and the recorded trace
/// counts the wide FLOPs — 2·nnz·(3·64), i.e. every stored nonzero times
/// every column of the stacked B.
#[test]
fn traced_wide_b_run_is_bitwise_identical_and_counts_wide_flops() {
    let reg = runnable_registry();
    let engine = Engine::new().unwrap();
    let mut rng = Rng::new(0x771D);
    let a = gen::uniform(64, 0.97, &mut rng);
    let gcoo = Gcoo::from_dense(&a, 8);
    assert!(gcoo.max_group_nnz() <= 64, "workload must fit the cap=64 artifact");
    let padded = gcoo.pad(64).unwrap();

    // Width-3 wide B: three 64-column request blocks side by side.
    let bs: Vec<Mat> = (0..3).map(|_| Mat::randn(64, 64, &mut rng)).collect();
    let mut wide = Mat::zeros(64, 3 * 64);
    for (k, b) in bs.iter().enumerate() {
        for i in 0..64 {
            wide.row_mut(i)[k * 64..(k + 1) * 64].copy_from_slice(b.row(i));
        }
    }

    let mut c_off = Mat::zeros(0, 0);
    engine.run_gcoo_slabs_into(&reg, padded.as_slabs(), &wide, true, &mut c_off).unwrap();
    let mut rec = TraceRecorder::new();
    let mut c_rec = Mat::zeros(0, 0);
    engine
        .run_gcoo_slabs_into_sink(&reg, padded.as_slabs(), &wide, true, &mut c_rec, &mut rec)
        .unwrap();
    assert_eq!(c_off, c_rec, "tracing must not perturb the fused wide-B product");

    let trace = rec.finish();
    assert_eq!(
        trace.flops,
        2 * gcoo.nnz() as u64 * (3 * 64) as u64,
        "wide-B trace must count 2·nnz·(k·n) FLOPs"
    );
    assert!(!trace.events.is_empty(), "wide-B trace must carry the kernel's events");
}

/// Mixed-signature traffic through the live coordinator: different As with
/// equal row counts must come back with each request's own product, and
/// the batch metrics must balance — Σ width·hist[width] equals jobs
/// processed and `conversions_amortized` equals Σ (width−1)·hist[width],
/// whatever widths the races produced.
#[test]
fn coordinator_fuses_safely_and_accounts_batches() {
    let reg = Arc::new(runnable_registry());
    let coord = Coordinator::new(
        Arc::clone(&reg),
        CoordinatorConfig { workers: 1, ..Default::default() },
    );
    let mut rng = Rng::new(0xC0);
    let a1 = gen::uniform(64, 0.97, &mut rng);
    let a2 = gen::uniform(64, 0.97, &mut rng); // same rows, different content
    let mut receivers = Vec::new();
    for i in 0..12u64 {
        let a = if i % 2 == 0 { &a1 } else { &a2 };
        let mut req = SpdmRequest::new(i, a.clone(), Mat::randn(64, 64, &mut rng));
        req.algo_hint = Some(Algo::Gcoo);
        req.verify = true; // the oracle catches any wrong-A fusion
        receivers.push(coord.submit(req).expect("queue open"));
    }
    // One shape-invalid request lands in the error counters.
    let bad = SpdmRequest::new(99, Mat::randn(8, 16, &mut rng), Mat::randn(16, 16, &mut rng));
    receivers.push(coord.submit(bad).expect("queue open"));
    let mut ok = 0;
    let mut failed = 0;
    for rx in receivers {
        let resp = rx.recv().expect("reply delivered");
        if resp.ok() {
            assert_eq!(
                resp.verified,
                Some(true),
                "request {} answered with the wrong A's product",
                resp.id
            );
            ok += 1;
        } else {
            failed += 1;
        }
    }
    assert_eq!((ok, failed), (12, 1));
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.completed, 12);
    assert_eq!(snap.errors, 1);
    assert_eq!(snap.verify_failures, 0);
    assert_eq!(
        snap.batched_jobs(),
        snap.completed + snap.errors,
        "batch-width histogram sums to jobs processed"
    );
    let expected_amortized: u64 = snap
        .batch_hist
        .iter()
        .enumerate()
        .map(|(w, &count)| (w as u64).saturating_sub(1) * count)
        .sum();
    assert_eq!(
        snap.conversions_amortized, expected_amortized,
        "conversions_amortized is (width − 1) per dequeued batch"
    );
    coord.shutdown();
}
