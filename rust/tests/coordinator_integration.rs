//! Integration: the coordinator end to end — routing, batching, verification,
//! backpressure, metrics, failure injection. Requires `make artifacts`.

use std::sync::Arc;

use gcoospdm::coordinator::{Algo, Coordinator, CoordinatorConfig, SpdmRequest};
use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::Registry;

fn registry() -> Option<Arc<Registry>> {
    match Registry::load("artifacts") {
        Ok(r) => Some(Arc::new(r)),
        Err(e) => {
            eprintln!("skipping coordinator integration ({e}); run `make artifacts`");
            None
        }
    }
}

fn request(id: u64, n: usize, sparsity: f64, seed: u64, verify: bool) -> SpdmRequest {
    let mut rng = Rng::new(seed);
    let a = gen::uniform(n, sparsity, &mut rng);
    let b = Mat::randn(n, n, &mut rng);
    let mut req = SpdmRequest::new(id, a, b);
    req.verify = verify;
    req
}

#[test]
fn sparse_request_routes_to_gcoo_and_verifies() {
    let Some(reg) = registry() else { return };
    let coord = Coordinator::new(reg, CoordinatorConfig { workers: 1, ..Default::default() });
    let resp = coord.run_sync(request(1, 256, 0.99, 1, true));
    assert!(resp.ok(), "{:?}", resp.error);
    assert_eq!(resp.algo, Algo::Gcoo);
    assert_eq!(resp.verified, Some(true));
    assert!(resp.kernel_s > 0.0);
    assert!(resp.convert_s > 0.0);
}

#[test]
fn dense_request_routes_to_dense() {
    let Some(reg) = registry() else { return };
    let coord = Coordinator::new(reg, CoordinatorConfig { workers: 1, ..Default::default() });
    let resp = coord.run_sync(request(2, 256, 0.30, 2, true));
    assert!(resp.ok());
    assert_eq!(resp.algo, Algo::DenseXla);
    assert_eq!(resp.verified, Some(true));
}

#[test]
fn hint_forces_algorithm() {
    let Some(reg) = registry() else { return };
    let coord = Coordinator::new(reg, CoordinatorConfig { workers: 1, ..Default::default() });
    let mut req = request(3, 256, 0.99, 3, true);
    req.algo_hint = Some(Algo::Csr);
    let resp = coord.run_sync(req);
    assert!(resp.ok(), "{:?}", resp.error);
    assert_eq!(resp.algo, Algo::Csr);
    assert_eq!(resp.verified, Some(true));
}

#[test]
fn odd_size_request_pads_and_trims() {
    let Some(reg) = registry() else { return };
    let coord = Coordinator::new(reg, CoordinatorConfig { workers: 1, ..Default::default() });
    let resp = coord.run_sync(request(4, 200, 0.99, 4, true));
    assert!(resp.ok(), "{:?}", resp.error);
    assert_eq!(resp.n_exec, 256, "200 should pad up to the 256 artifact");
    assert_eq!(resp.verified, Some(true));
    assert_eq!(resp.c.as_ref().unwrap().rows, 200, "result trimmed back");
}

#[test]
fn oversized_request_fails_cleanly() {
    let Some(reg) = registry() else { return };
    let coord = Coordinator::new(reg, CoordinatorConfig { workers: 1, ..Default::default() });
    let resp = coord.run_sync(request(5, 2048, 0.999, 5, false));
    assert!(!resp.ok(), "no artifact covers n=2048; must fail with an error");
}

#[test]
fn non_square_request_rejected() {
    let Some(reg) = registry() else { return };
    let coord = Coordinator::new(reg, CoordinatorConfig { workers: 1, ..Default::default() });
    let mut rng = Rng::new(6);
    let req = SpdmRequest::new(6, Mat::randn(8, 16, &mut rng), Mat::randn(16, 16, &mut rng));
    let resp = coord.run_sync(req);
    assert!(!resp.ok());
    assert!(resp.error.unwrap().contains("shape"));
}

#[test]
fn concurrent_mixed_workload_completes_with_metrics() {
    let Some(reg) = registry() else { return };
    let coord = Coordinator::new(
        reg,
        CoordinatorConfig { workers: 2, queue_cap: 16, ..Default::default() },
    );
    // Mixed sizes + sparsities; batcher groups the same-n jobs.
    let mut receivers = Vec::new();
    for i in 0..10u64 {
        let n = if i % 2 == 0 { 256 } else { 200 };
        let s = if i % 3 == 0 { 0.5 } else { 0.99 };
        receivers.push(coord.submit(request(i, n, s, 10 + i, true)).expect("queue open"));
    }
    let mut ok = 0;
    for rx in receivers {
        let resp = rx.recv().unwrap();
        assert!(resp.ok(), "{:?}", resp.error);
        assert_eq!(resp.verified, Some(true));
        ok += 1;
    }
    assert_eq!(ok, 10);
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.completed, 10);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.verify_failures, 0);
    // Every n=256 request runs borrow-path slabs and matching-size B/C.
    assert!(snap.copies_avoided > 0, "zero-copy paths must be exercised");
    assert!(snap.per_algo.get("gcoo").copied().unwrap_or(0) > 0);
    assert!(snap.per_algo.get("dense_xla").copied().unwrap_or(0) > 0);
    assert!(snap.p99_s >= snap.p50_s);
}

#[test]
fn shutdown_drains() {
    let Some(reg) = registry() else { return };
    let coord = Coordinator::new(reg, CoordinatorConfig { workers: 1, ..Default::default() });
    let rx = coord.submit(request(1, 256, 0.99, 20, false)).expect("queue open");
    coord.shutdown();
    // The submitted job must have been completed before shutdown returned.
    assert!(rx.recv().unwrap().ok());
}
