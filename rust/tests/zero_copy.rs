//! Zero-copy pipeline invariants, runnable without `make artifacts`: the
//! engine only requires artifact *files to exist*, so these tests fabricate
//! a registry whose entries point at stub files under `target/`.
//!
//! Covers the acceptance criteria of the workspace/arena refactor:
//! * matching-cap GCOO execution performs **zero** slab copies (asserted
//!   via the copy counters);
//! * borrowed vs. cloned/re-padded slab execution produce identical C;
//! * `process_one` at a matching geometry reports no copied bytes end to
//!   end and the metrics pair surfaces through the coordinator.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use gcoospdm::coordinator::{
    process_one, Algo, Coordinator, CoordinatorConfig, SpdmRequest,
};
use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::prop::{check, Config};
use gcoospdm::rng::Rng;
use gcoospdm::runtime::{Engine, Registry};
use gcoospdm::simgpu::TraceRecorder;
use gcoospdm::sparse::{Csr, Ell, Gcoo};

/// Registry with gcoo caps {64, 512} + dense at n=64, backed by a real
/// (stub) file so `Engine::load` succeeds.
fn runnable_registry() -> Registry {
    let dir = PathBuf::from("target/zero_copy_artifacts");
    std::fs::create_dir_all(&dir).expect("create stub artifact dir");
    std::fs::write(dir.join("stub.hlo.txt"), b"stub").expect("write stub artifact");
    let manifest = r#"{"artifacts": [
        {"name": "gcoo_n64_cap64", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "gcoo_n64_cap512", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "csr_n64_rowcap64", "algo": "csr", "n": 64,
         "params": {"rp": 8, "rowcap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "dense_xla_n64", "algo": "dense_xla", "n": 64,
         "params": {}, "inputs": [], "file": "stub.hlo.txt"}
    ]}"#;
    Registry::from_manifest_json(manifest, dir).expect("stub manifest parses")
}

#[test]
fn matching_cap_execution_is_zero_copy() {
    let reg = runnable_registry();
    let engine = Engine::new().unwrap();
    let mut rng = Rng::new(1);
    let a = gen::uniform(64, 0.95, &mut rng);
    let b = Mat::randn(64, 64, &mut rng);
    let gcoo = Gcoo::from_dense(&a, 8);
    assert!(gcoo.max_group_nnz() <= 64, "workload must fit the cap=64 artifact");
    // Pad to exactly the artifact's capacity: the engine must borrow.
    let padded = gcoo.pad(64).unwrap();
    let out = engine.run_gcoo(&reg, &padded, &b, true).unwrap();
    assert_eq!(out.copy.bytes_copied, 0, "matching cap must copy zero slab bytes");
    assert_eq!(out.copy.copies_avoided, 1);
    assert!(out.c.allclose(&a.matmul(&b), 1e-3, 1e-3));
}

#[test]
fn borrowed_and_repadded_execution_agree() {
    // Property: for random GCOO matrices, executing via the borrowed
    // matching-cap slabs and via a mismatched-cap (engine re-pads) path
    // produce the identical C.
    let reg = runnable_registry();
    let engine = Engine::new().unwrap();
    check(
        Config { cases: 24, base_seed: 0x2C0F, max_size: 64, ..Default::default() },
        |g| {
            let sparsity = g.f64_in(0.9, 0.99);
            let a = gen::uniform(64, sparsity, &mut g.rng);
            let b = Mat::randn(64, 64, &mut g.rng);
            (a, b)
        },
        |(a, b)| {
            let gcoo = Gcoo::from_dense(a, 8);
            if gcoo.max_group_nnz() > 64 {
                return Ok(()); // rare outlier: would route to cap512 anyway
            }
            let borrowed = gcoo.pad(64).map_err(|e| e.to_string())?;
            let out_b = engine.run_gcoo(&reg, &borrowed, b, true).map_err(|e| e.to_string())?;
            if out_b.copy.bytes_copied != 0 {
                return Err("matching-cap path copied slab bytes".into());
            }
            // Non-exported cap: the engine must re-pad (copying) yet agree.
            let mismatched = gcoo.pad(gcoo.max_group_nnz().max(1)).map_err(|e| e.to_string())?;
            let out_m = engine.run_gcoo(&reg, &mismatched, b, true).map_err(|e| e.to_string())?;
            if mismatched.cap != 64 && out_m.copy.bytes_copied == 0 {
                return Err("mismatched cap should have re-padded".into());
            }
            if out_b.c != out_m.c {
                return Err("borrowed vs re-padded slab execution differ".into());
            }
            Ok(())
        },
    );
}

/// TraceSink overhead contract: running the instrumented kernels with the
/// sink disabled (the `NullSink` delegation every serving call takes) must
/// produce C bitwise identical to a traced run, and the disabled path must
/// stay allocation-free — re-running into the same C reuses its buffer.
#[test]
fn tracing_does_not_perturb_gcoo_output_and_sink_off_is_allocation_free() {
    let reg = runnable_registry();
    let engine = Engine::new().unwrap();
    let mut rng = Rng::new(0x51AB);
    let a = gen::uniform(64, 0.95, &mut rng);
    let b = Mat::randn(64, 64, &mut rng);
    let gcoo = Gcoo::from_dense(&a, 8);
    assert!(gcoo.max_group_nnz() <= 64, "workload must fit the cap=64 artifact");
    let padded = gcoo.pad(64).unwrap();

    let mut c_off = Mat::zeros(0, 0);
    engine.run_gcoo_slabs_into(&reg, padded.as_slabs(), &b, true, &mut c_off).unwrap();
    let mut rec = TraceRecorder::new();
    let mut c_rec = Mat::zeros(0, 0);
    engine
        .run_gcoo_slabs_into_sink(&reg, padded.as_slabs(), &b, true, &mut c_rec, &mut rec)
        .unwrap();
    assert_eq!(c_off, c_rec, "traced and sink-off gcoo runs must be bitwise identical");
    let trace = rec.finish();
    assert!(!trace.events.is_empty(), "recorder must capture the kernel's events");
    assert!(trace.flops > 0, "recorder must capture the kernel's FLOPs");

    // Allocation-free serving: the sink-off rerun must reuse C's buffer.
    let ptr = c_off.row(0).as_ptr();
    engine.run_gcoo_slabs_into(&reg, padded.as_slabs(), &b, true, &mut c_off).unwrap();
    assert_eq!(ptr, c_off.row(0).as_ptr(), "sink-off rerun must not reallocate C");
    assert_eq!(c_off, c_rec, "rerun must reproduce the identical product");
}

/// Same overhead contract on the ELL (csr-kernel) path.
#[test]
fn tracing_does_not_perturb_ell_output_and_sink_off_is_allocation_free() {
    let reg = runnable_registry();
    let engine = Engine::new().unwrap();
    let mut rng = Rng::new(0x51AC);
    let a = gen::uniform(64, 0.95, &mut rng);
    let b = Mat::randn(64, 64, &mut rng);
    let ell = Ell::from_csr(&Csr::from_dense(&a), 64).unwrap();

    let mut c_off = Mat::zeros(0, 0);
    engine.run_ell_slabs_into(&reg, ell.as_slabs(), &b, &mut c_off).unwrap();
    let mut rec = TraceRecorder::new();
    let mut c_rec = Mat::zeros(0, 0);
    engine.run_ell_slabs_into_sink(&reg, ell.as_slabs(), &b, &mut c_rec, &mut rec).unwrap();
    assert_eq!(c_off, c_rec, "traced and sink-off ell runs must be bitwise identical");
    assert!(!rec.finish().events.is_empty(), "recorder must capture the kernel's events");

    let ptr = c_off.row(0).as_ptr();
    engine.run_ell_slabs_into(&reg, ell.as_slabs(), &b, &mut c_off).unwrap();
    assert_eq!(ptr, c_off.row(0).as_ptr(), "sink-off rerun must not reallocate C");
    assert_eq!(c_off, c_rec, "rerun must reproduce the identical product");
}

#[test]
fn process_one_matching_geometry_reports_zero_copied_bytes() {
    // n == n_exec and the planned cap equals the converted cap by
    // construction → the full request pipeline moves zero redundant bytes
    // (B borrowed, A scattered once into slabs, C moved out).
    let reg = runnable_registry();
    let engine = Engine::new().unwrap();
    let cfg = CoordinatorConfig { workers: 1, ..Default::default() };
    let mut rng = Rng::new(7);
    let a = gen::uniform(64, 0.99, &mut rng);
    let b = Mat::randn(64, 64, &mut rng);
    let mut req = SpdmRequest::new(1, a, b);
    req.verify = true;
    let resp = process_one(&engine, &reg, &cfg, &req, Instant::now());
    assert!(resp.ok(), "{:?}", resp.error);
    assert_eq!(resp.algo, Algo::Gcoo);
    assert_eq!(resp.verified, Some(true));
    assert_eq!(resp.bytes_copied, 0, "matching geometry must be fully zero-copy");
    assert!(resp.copies_avoided >= 3, "B borrow + slab borrow + C move");
}

#[test]
fn coordinator_surfaces_copy_counters() {
    let reg = Arc::new(runnable_registry());
    let coord = Coordinator::new(reg, CoordinatorConfig { workers: 1, ..Default::default() });
    let mut rng = Rng::new(9);
    // One matching-size sparse request and one small (padded) request,
    // through the typed submit path.
    for (id, n) in [(1u64, 64usize), (2, 48)] {
        let a = gen::uniform(n, 0.99, &mut rng);
        let b = Mat::randn(n, n, &mut rng);
        let mut req = SpdmRequest::new(id, a, b);
        req.verify = true;
        let resp = coord
            .submit(req)
            .expect("queue open")
            .recv()
            .expect("reply delivered");
        assert!(resp.ok(), "{:?}", resp.error);
        assert_eq!(resp.verified, Some(true));
        if n == 64 {
            assert_eq!(resp.bytes_copied, 0);
        } else {
            assert!(resp.bytes_copied > 0, "padded request must count its pad/trim copies");
        }
    }
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.completed, 2);
    assert!(snap.copies_avoided >= 3);
    assert!(snap.bytes_copied > 0);
    assert!(snap.render().contains("avoided"));
    coord.shutdown();
}
