//! Integration: the TCP serving loop — protocol round trips against a live
//! server. The artifact-backed sessions require `make artifacts`; the
//! stats-endpoint test fabricates a stub registry under `target/` (the
//! engine only needs artifact files to exist) so it always runs.

use std::sync::Arc;

use gcoospdm::coordinator::{Coordinator, CoordinatorConfig};
use gcoospdm::runtime::Registry;
use gcoospdm::serve::{Client, Server, ServerConfig};

/// Boot a server on an ephemeral port; returns (addr, server thread handle).
fn boot() -> Option<(String, std::thread::JoinHandle<()>)> {
    let reg = match Registry::load("artifacts") {
        Ok(r) => Arc::new(r),
        Err(e) => {
            eprintln!("skipping serve integration ({e}); run `make artifacts`");
            return None;
        }
    };
    let coord = Arc::new(Coordinator::new(
        reg,
        CoordinatorConfig { workers: 1, ..Default::default() },
    ));
    let server = Server::bind(&ServerConfig::ephemeral(), coord).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        let _ = server.run();
    });
    Some((addr, handle))
}

#[test]
fn full_protocol_session() {
    let Some((addr, handle)) = boot() else { return };
    let mut client = Client::connect(&addr).unwrap();

    // ping
    let r = client.ping(1).unwrap();
    assert!(r.ok);
    assert_eq!(r.id, 1);

    // synthetic spdm, auto-routed, verified
    let r = client.spdm_synthetic(2, 256, 0.99, "uniform", 7, "auto", true).unwrap();
    assert!(r.ok, "{:?}", r.error);
    assert_eq!(r.algo.as_deref(), Some("gcoo"));
    assert_eq!(r.verified, Some(true));
    assert!(r.kernel_ms.unwrap() > 0.0);
    assert!(r.checksum.is_some());

    // forced dense
    let r = client.spdm_synthetic(3, 256, 0.99, "uniform", 7, "dense_xla", true).unwrap();
    assert!(r.ok);
    assert_eq!(r.algo.as_deref(), Some("dense_xla"));
    assert_eq!(r.verified, Some(true));

    // inline payload: 2x2 identity times known B
    let a = vec![1.0, 0.0, 0.0, 1.0];
    let b = vec![5.0, 6.0, 7.0, 8.0];
    let r = client.spdm_inline(4, 2, &a, &b, true).unwrap();
    assert!(r.ok, "{:?}", r.error);
    assert_eq!(r.verified, Some(true));
    assert!((r.checksum.unwrap() - 26.0).abs() < 1e-3, "sum of B entries");

    // deterministic checksum: same synthetic request twice
    let c1 = client.spdm_synthetic(5, 128, 0.95, "banded", 3, "auto", false).unwrap();
    let c2 = client.spdm_synthetic(6, 128, 0.95, "banded", 3, "auto", false).unwrap();
    assert_eq!(c1.checksum, c2.checksum);

    // error path: bogus pattern
    let r = client.spdm_synthetic(7, 64, 0.9, "not_a_pattern", 0, "auto", false).unwrap();
    assert!(!r.ok);
    assert!(r.error.unwrap().contains("pattern"));

    // metrics reflect the traffic
    let m = client.metrics(8).unwrap();
    assert!(m.ok);
    let text = m.metrics.unwrap();
    assert!(text.contains("completed"), "{text}");

    // shutdown terminates the accept loop
    let r = client.shutdown(9).unwrap();
    assert!(r.ok);
    handle.join().unwrap();
}

/// Boot a server over a stub registry (no `make artifacts` needed).
fn boot_stub() -> (String, std::thread::JoinHandle<()>) {
    let dir = std::path::PathBuf::from("target/serve_stats_artifacts");
    std::fs::create_dir_all(&dir).expect("create stub artifact dir");
    std::fs::write(dir.join("stub.hlo.txt"), b"stub").expect("write stub artifact");
    let manifest = r#"{"artifacts": [
        {"name": "gcoo_n64_cap512", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "csr_n64_rowcap64", "algo": "csr", "n": 64,
         "params": {"rp": 8, "rowcap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "dense_xla_n64", "algo": "dense_xla", "n": 64,
         "params": {}, "inputs": [], "file": "stub.hlo.txt"}
    ]}"#;
    let reg = Arc::new(Registry::from_manifest_json(manifest, dir).expect("stub manifest"));
    let coord = Arc::new(Coordinator::new(
        reg,
        CoordinatorConfig { workers: 1, ..Default::default() },
    ));
    let server = Server::bind(&ServerConfig::ephemeral(), coord).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        let _ = server.run();
    });
    (addr, handle)
}

/// The structured `stats` endpoint surfaces the batch metrics: the reply is
/// machine-parseable JSON whose batch-width histogram sums to the jobs
/// processed and whose `conversions_amortized` is (width−1) per batch.
#[test]
fn stats_endpoint_reports_batch_counters() {
    let (addr, handle) = boot_stub();
    let mut client = Client::connect(&addr).unwrap();
    for i in 0..4u64 {
        let r = client.spdm_synthetic(i, 64, 0.97, "uniform", 7 + i, "gcoo", true).unwrap();
        assert!(r.ok, "{:?}", r.error);
        assert_eq!(r.verified, Some(true));
    }
    let s = client.stats(50).unwrap();
    assert!(s.ok);
    let text = s.metrics.expect("stats reply carries the JSON snapshot");
    let v = gcoospdm::json::parse(&text).expect("stats payload is valid JSON");
    assert_eq!(v.get("completed").unwrap().as_u64(), Some(4));
    let errors = v.get("errors").unwrap().as_u64().unwrap();
    let hist = v.get("batch_hist").unwrap().as_arr().unwrap();
    let jobs: u64 = hist
        .iter()
        .enumerate()
        .map(|(w, c)| w as u64 * c.as_u64().unwrap())
        .sum();
    assert_eq!(jobs, 4 + errors, "batch histogram sums to jobs processed");
    let amortized = v.get("conversions_amortized").unwrap().as_u64().unwrap();
    let expected: u64 = hist
        .iter()
        .enumerate()
        .map(|(w, c)| (w as u64).saturating_sub(1) * c.as_u64().unwrap())
        .sum();
    assert_eq!(amortized, expected, "(width−1) per dequeued batch");
    assert!(v.get("copies_avoided").unwrap().as_u64().is_some());
    // The human-readable render carries the same counters.
    let m = client.metrics(51).unwrap();
    assert!(m.ok);
    assert!(m.metrics.unwrap().contains("conversions amortized"));
    client.shutdown(52).unwrap();
    handle.join().unwrap();
}

#[test]
fn multiple_clients() {
    let Some((addr, handle)) = boot() else { return };
    let mut joins = Vec::new();
    for c in 0..3u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let r = client
                .spdm_synthetic(100 + c, 128, 0.99, "uniform", c, "auto", true)
                .unwrap();
            assert!(r.ok, "{:?}", r.error);
            assert_eq!(r.verified, Some(true));
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let mut client = Client::connect(&addr).unwrap();
    client.shutdown(999).unwrap();
    handle.join().unwrap();
}
