//! Integration: the TCP serving loop — protocol round trips against a live
//! server backed by real artifacts. Requires `make artifacts`.

use std::sync::Arc;

use gcoospdm::coordinator::{Coordinator, CoordinatorConfig};
use gcoospdm::runtime::Registry;
use gcoospdm::serve::{Client, Server, ServerConfig};

/// Boot a server on an ephemeral port; returns (addr, server thread handle).
fn boot() -> Option<(String, std::thread::JoinHandle<()>)> {
    let reg = match Registry::load("artifacts") {
        Ok(r) => Arc::new(r),
        Err(e) => {
            eprintln!("skipping serve integration ({e}); run `make artifacts`");
            return None;
        }
    };
    let coord = Arc::new(Coordinator::new(
        reg,
        CoordinatorConfig { workers: 1, ..Default::default() },
    ));
    let server = Server::bind(&ServerConfig::ephemeral(), coord).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        let _ = server.run();
    });
    Some((addr, handle))
}

#[test]
fn full_protocol_session() {
    let Some((addr, handle)) = boot() else { return };
    let mut client = Client::connect(&addr).unwrap();

    // ping
    let r = client.ping(1).unwrap();
    assert!(r.ok);
    assert_eq!(r.id, 1);

    // synthetic spdm, auto-routed, verified
    let r = client.spdm_synthetic(2, 256, 0.99, "uniform", 7, "auto", true).unwrap();
    assert!(r.ok, "{:?}", r.error);
    assert_eq!(r.algo.as_deref(), Some("gcoo"));
    assert_eq!(r.verified, Some(true));
    assert!(r.kernel_ms.unwrap() > 0.0);
    assert!(r.checksum.is_some());

    // forced dense
    let r = client.spdm_synthetic(3, 256, 0.99, "uniform", 7, "dense_xla", true).unwrap();
    assert!(r.ok);
    assert_eq!(r.algo.as_deref(), Some("dense_xla"));
    assert_eq!(r.verified, Some(true));

    // inline payload: 2x2 identity times known B
    let a = vec![1.0, 0.0, 0.0, 1.0];
    let b = vec![5.0, 6.0, 7.0, 8.0];
    let r = client.spdm_inline(4, 2, &a, &b, true).unwrap();
    assert!(r.ok, "{:?}", r.error);
    assert_eq!(r.verified, Some(true));
    assert!((r.checksum.unwrap() - 26.0).abs() < 1e-3, "sum of B entries");

    // deterministic checksum: same synthetic request twice
    let c1 = client.spdm_synthetic(5, 128, 0.95, "banded", 3, "auto", false).unwrap();
    let c2 = client.spdm_synthetic(6, 128, 0.95, "banded", 3, "auto", false).unwrap();
    assert_eq!(c1.checksum, c2.checksum);

    // error path: bogus pattern
    let r = client.spdm_synthetic(7, 64, 0.9, "not_a_pattern", 0, "auto", false).unwrap();
    assert!(!r.ok);
    assert!(r.error.unwrap().contains("pattern"));

    // metrics reflect the traffic
    let m = client.metrics(8).unwrap();
    assert!(m.ok);
    let text = m.metrics.unwrap();
    assert!(text.contains("completed"), "{text}");

    // shutdown terminates the accept loop
    let r = client.shutdown(9).unwrap();
    assert!(r.ok);
    handle.join().unwrap();
}

#[test]
fn multiple_clients() {
    let Some((addr, handle)) = boot() else { return };
    let mut joins = Vec::new();
    for c in 0..3u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let r = client
                .spdm_synthetic(100 + c, 128, 0.99, "uniform", c, "auto", true)
                .unwrap();
            assert!(r.ok, "{:?}", r.error);
            assert_eq!(r.verified, Some(true));
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let mut client = Client::connect(&addr).unwrap();
    client.shutdown(999).unwrap();
    handle.join().unwrap();
}
