//! Property-based invariants (via the in-repo `prop` substrate): format
//! round trips, conversion equivalences, simulator monotonicity, selector
//! sanity, queue behavior, and the batch-affinity A-signature — the
//! proptest-style layer of the test suite.

use gcoospdm::convert;
use gcoospdm::coordinator::{batch_affine, ASig, SpdmRequest};
use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::prop::{check, Config};
use gcoospdm::rng::Rng;
use gcoospdm::simgpu::{self, GcooStructure, SyntheticUniform, WalkConfig, TITANX};
use gcoospdm::sparse::{Coo, Csr, Ell, Gcoo, ToDense};

/// A random matrix case for format properties.
#[derive(Debug)]
struct MatCase {
    n: usize,
    p: usize,
    pattern: gen::Pattern,
    sparsity: f64,
    seed: u64,
}

fn mat_case(g: &mut gcoospdm::prop::Gen) -> MatCase {
    let n = 8 * g.usize_in(1, (g.size / 2).max(2)); // multiples of 8 up to ~size*4
    MatCase {
        n,
        p: *g.pick(&[1usize, 2, 4, 8, 16]),
        pattern: *g.pick(&gen::Pattern::ALL),
        sparsity: g.f64_in(0.0, 0.999),
        seed: g.rng.next_u64(),
    }
}

fn materialize(c: &MatCase) -> Mat {
    let mut rng = Rng::new(c.seed);
    gen::generate(c.pattern, c.n, c.sparsity, &mut rng)
}

#[test]
fn prop_every_format_round_trips() {
    check(Config { cases: 48, ..Default::default() }, mat_case, |c| {
        let a = materialize(c);
        let coo = Coo::from_dense(&a);
        if coo.to_dense() != a {
            return Err("coo round trip".into());
        }
        let csr = Csr::from_dense(&a);
        if csr.to_dense() != a {
            return Err("csr round trip".into());
        }
        let gcoo = Gcoo::from_dense(&a, c.p);
        gcoo.validate().map_err(|e| e.to_string())?;
        if gcoo.to_dense() != a {
            return Err("gcoo round trip".into());
        }
        Ok(())
    });
}

#[test]
fn prop_conversion_paths_agree() {
    check(Config { cases: 32, ..Default::default() }, mat_case, |c| {
        let a = materialize(c);
        let direct = Gcoo::from_dense(&a, c.p);
        let via_csr = Gcoo::from_csr(&Csr::from_dense(&a), c.p);
        if direct != via_csr {
            return Err("from_dense != from_csr".into());
        }
        let (parallel, _t) = convert::dense_to_gcoo_parallel(&a, c.p, 3);
        if parallel != direct {
            return Err("parallel != sequential".into());
        }
        Ok(())
    });
}

#[test]
fn prop_padded_forms_preserve_values() {
    check(Config { cases: 32, ..Default::default() }, mat_case, |c| {
        let a = materialize(c);
        let gcoo = Gcoo::from_dense(&a, c.p);
        let cap = gcoo.max_group_nnz().max(1);
        let padded = gcoo.pad(cap).map_err(|e| e.to_string())?;
        // sum of padded vals == sum of matrix (padding adds zeros only)
        let sum_pad: f64 = padded.vals.iter().map(|v| *v as f64).sum();
        let sum_mat: f64 = a.data.iter().map(|v| *v as f64).sum();
        if (sum_pad - sum_mat).abs() > 1e-3 * sum_mat.abs().max(1.0) {
            return Err(format!("value sum drift: {sum_pad} vs {sum_mat}"));
        }
        let csr = Csr::from_dense(&a);
        let ell = Ell::from_csr(&csr, csr.max_row_nnz().max(1)).map_err(|e| e.to_string())?;
        if ell.to_dense() != a {
            return Err("ell round trip".into());
        }
        Ok(())
    });
}

#[test]
fn prop_footprint_formulas_match_structures() {
    check(Config { cases: 32, ..Default::default() }, mat_case, |c| {
        let a = materialize(c);
        let gcoo = Gcoo::from_dense(&a, c.p);
        // Table I formula vs actual array lengths (elements).
        let actual = gcoo.vals.len() + gcoo.rows.len() + gcoo.cols.len()
            + gcoo.g_idxes.len() + gcoo.nnz_per_group.len();
        let formula = gcoospdm::sparse::gcoo_elements(gcoo.nnz(), c.n, c.p);
        if actual != formula {
            return Err(format!("gcoo elements {actual} != formula {formula}"));
        }
        Ok(())
    });
}

#[test]
fn prop_reuse_never_increases_tex_traffic() {
    // The bv-reuse scan can only remove B fetches, never add them.
    check(
        Config { cases: 12, max_size: 24, ..Default::default() },
        |g| MatCase {
            n: 8 * g.usize_in(4, 24),
            p: 8,
            pattern: *g.pick(&gen::Pattern::ALL),
            sparsity: g.f64_in(0.5, 0.995),
            seed: g.rng.next_u64(),
        },
        |c| {
            let a = materialize(c);
            let st = GcooStructure::new(&Gcoo::from_dense(&a, 8));
            let cfg = WalkConfig { sample_blocks: 16, ..Default::default() };
            let (with, f1) = simgpu::gcoo_walk(&st, &TITANX, &cfg, true);
            let (without, f2) = simgpu::gcoo_walk(&st, &TITANX, &cfg, false);
            if f1 != f2 {
                return Err("flops must not depend on reuse".into());
            }
            if with.l1_tex > without.l1_tex {
                return Err(format!("reuse added traffic: {} > {}", with.l1_tex, without.l1_tex));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sim_time_decreases_with_sparsity() {
    // On synthetic uniform structure, higher sparsity ⇒ less work ⇒ faster
    // (for both sparse kernels). Dense stays constant by construction.
    check(
        Config { cases: 10, max_size: 16, ..Default::default() },
        |g| (512 + 128 * g.usize_in(0, 8), g.f64_in(0.8, 0.95), g.rng.next_u64()),
        |&(n, s, seed)| {
            let cfg = WalkConfig { sample_blocks: 24, ..Default::default() };
            let lo = SyntheticUniform::new(n, s, 8, seed);
            let hi = SyntheticUniform::new(n, (s + 0.04).min(0.9995), 8, seed);
            let t_lo = simgpu::simulate_gcoo(&lo, &TITANX, &cfg, true).time_s();
            let t_hi = simgpu::simulate_gcoo(&hi, &TITANX, &cfg, true).time_s();
            if t_hi > t_lo * 1.05 {
                return Err(format!("sparser slower: {t_hi} vs {t_lo} (n={n}, s={s})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_a_signature_equal_matrices_equal_signature() {
    // Soundness of the batch key: the signature is a pure function of the
    // matrix content, its stored dims/nnz agree with the matrix, and a
    // same-dims/same-nnz value perturbation (the near-collision case)
    // always changes it — so the batcher cannot fuse different As.
    check(Config { cases: 48, base_seed: 0xA51, ..Default::default() }, mat_case, |c| {
        let a = materialize(c);
        let sig = ASig::of(&a);
        if sig != ASig::of(&a.clone()) {
            return Err("equal matrices must have equal signatures".into());
        }
        if (sig.rows, sig.cols, sig.nnz) != (a.rows, a.cols, a.nnz()) {
            return Err("signature dims/nnz disagree with the matrix".into());
        }
        if let Some(idx) = a.data.iter().position(|&v| v != 0.0) {
            let mut near = a.clone();
            near.data[idx] *= 2.0; // exponent bump: nonzero stays nonzero
            let sig2 = ASig::of(&near);
            if (sig2.rows, sig2.cols, sig2.nnz) != (sig.rows, sig.cols, sig.nnz) {
                return Err("perturbation was supposed to preserve dims/nnz".into());
            }
            if sig2 == sig {
                return Err("same-dims/same-nnz content change not detected".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_a_signature_inequality_is_safe_non_batching() {
    // The unsound direction cannot happen: requests whose signatures differ
    // never satisfy the batch predicate, and (for these generated cases)
    // signatures only coincide when the content is identical.
    check(
        Config { cases: 32, base_seed: 0xA52, ..Default::default() },
        |g| (mat_case(g), mat_case(g)),
        |(c1, c2)| {
            let (a1, a2) = (materialize(c1), materialize(c2));
            let (b1, b2) = (Mat::zeros(a1.rows, a1.rows), Mat::zeros(a2.rows, a2.rows));
            let r1 = SpdmRequest::new(1, a1, b1);
            let r2 = SpdmRequest::new(2, a2, b2);
            if r1.a_sig != r2.a_sig && batch_affine(&r1, &r2) {
                return Err("unequal signatures must never batch".into());
            }
            let (d1, d2) = (
                &r1.a.as_inline().expect("inline request").data,
                &r2.a.as_inline().expect("inline request").data,
            );
            if r1.a_sig == r2.a_sig && d1 != d2 {
                return Err("signature collision on different content".into());
            }
            Ok(())
        },
    );
}

#[test]
fn a_signature_seeded_near_collision_does_not_batch() {
    // Fixed-seed regression: same dims, same nnz, different values — the
    // pair a rows+nnz key could not tell apart — must not batch.
    let mut rng = Rng::new(0xBEEF);
    let a1 = gen::uniform(32, 0.9, &mut rng);
    let mut a2 = a1.clone();
    let idx = a2.data.iter().position(|&v| v != 0.0).expect("nonzero entry");
    a2.data[idx] *= 2.0;
    assert_eq!(a1.nnz(), a2.nnz());
    let r1 = SpdmRequest::new(1, a1, Mat::zeros(32, 32));
    let r2 = SpdmRequest::new(2, a2, Mat::zeros(32, 32));
    assert_eq!(
        (r1.a_sig.rows, r1.a_sig.cols, r1.a_sig.nnz),
        (r2.a_sig.rows, r2.a_sig.cols, r2.a_sig.nnz)
    );
    assert_ne!(r1.a_sig, r2.a_sig, "value hash must split the near-collision");
    assert!(!batch_affine(&r1, &r2));
}

#[test]
fn prop_queue_batches_are_affine_and_complete() {
    use gcoospdm::coordinator::BoundedQueue;
    check(
        Config { cases: 24, ..Default::default() },
        |g| {
            let len = g.usize_in(1, 40);
            (0..len).map(|_| g.usize_in(0, 3)).collect::<Vec<usize>>()
        },
        |shapes| {
            let q = BoundedQueue::new(shapes.len().max(1));
            for (i, &s) in shapes.iter().enumerate() {
                q.try_push((s, i)).map_err(|_| "push failed")?;
            }
            q.close();
            let mut seen = vec![false; shapes.len()];
            while let Some(batch) = q.pop_batch(8, |h, c| h.0 == c.0) {
                let shape = batch[0].0;
                if batch.len() > 8 {
                    return Err("batch exceeded max".into());
                }
                for (s, i) in batch {
                    if s != shape {
                        return Err("mixed shapes in batch".into());
                    }
                    if seen[i] {
                        return Err(format!("job {i} delivered twice"));
                    }
                    seen[i] = true;
                }
            }
            if !seen.iter().all(|&x| x) {
                return Err("jobs lost".into());
            }
            Ok(())
        },
    );
}
