//! Cross-language format agreement: the python builders
//! (compile/kernels/ref.py) and the rust `sparse` module must produce
//! byte-identical GCOO/ELL layouts for the same matrix.
//!
//! The fixture (tests_fixtures/format_fixture.json, written by
//! python/scripts/write_fixtures.py) uses a closed-form matrix rule so both
//! sides construct exactly the same input without sharing an RNG.

use gcoospdm::json;
use gcoospdm::ndarray::Mat;
use gcoospdm::sparse::{Csr, Ell, Gcoo};

fn rule_matrix(n: usize) -> Mat {
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            if (i * 31 + j * 17) % 7 == 0 {
                a[(i, j)] = ((i + 2 * j) % 5 + 1) as f32;
            }
        }
    }
    a
}

fn load_fixture() -> Option<json::Value> {
    let text = std::fs::read_to_string("tests_fixtures/format_fixture.json").ok()?;
    json::parse(&text).ok()
}

#[test]
fn gcoo_layout_matches_python() {
    let Some(fx) = load_fixture() else {
        eprintln!("fixture missing; run python/scripts/write_fixtures.py");
        return;
    };
    let n = fx.get("n").unwrap().as_usize().unwrap();
    let p = fx.get("p").unwrap().as_usize().unwrap();
    let a = rule_matrix(n);
    let gcoo = Gcoo::from_dense(&a, p);
    assert_eq!(gcoo.nnz(), fx.get("nnz").unwrap().as_usize().unwrap());

    let bands = fx.get("gcoo_bands").unwrap().as_arr().unwrap();
    assert_eq!(bands.len(), gcoo.num_groups());
    for (gi, band) in bands.iter().enumerate() {
        let want_vals: Vec<f32> = band
            .get("vals")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let want_rows: Vec<u32> = band
            .get("rows")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_u64().unwrap() as u32)
            .collect();
        let want_cols: Vec<u32> = band
            .get("cols")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_u64().unwrap() as u32)
            .collect();
        let got: Vec<(u32, u32, f32)> = gcoo.group(gi).collect();
        let got_rows: Vec<u32> = got.iter().map(|e| e.0).collect();
        let got_cols: Vec<u32> = got.iter().map(|e| e.1).collect();
        let got_vals: Vec<f32> = got.iter().map(|e| e.2).collect();
        assert_eq!(got_rows, want_rows, "band {gi} rows");
        assert_eq!(got_cols, want_cols, "band {gi} cols");
        assert_eq!(got_vals, want_vals, "band {gi} vals");
    }
}

#[test]
fn ell_layout_matches_python() {
    let Some(fx) = load_fixture() else {
        return;
    };
    let n = fx.get("n").unwrap().as_usize().unwrap();
    let a = rule_matrix(n);
    let csr = Csr::from_dense(&a);
    let ell = Ell::from_csr(&csr, n).unwrap();
    let rows = fx.get("ell_rows").unwrap().as_arr().unwrap();
    for (i, row) in rows.iter().enumerate() {
        let want_vals: Vec<f32> = row
            .get("vals")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let k = want_vals.len();
        assert_eq!(&ell.vals[i * n..i * n + k], &want_vals[..], "row {i} vals");
        // rest of the row must be zero padding
        assert!(ell.vals[i * n + k..(i + 1) * n].iter().all(|v| *v == 0.0));
        let want_cols: Vec<i32> = row
            .get("cols")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_u64().unwrap() as i32)
            .collect();
        assert_eq!(&ell.cols[i * n..i * n + k], &want_cols[..], "row {i} cols");
    }
}
