//! Cluster differential: a K-node sharded cluster behind the stateless
//! router must answer every data-plane request **bitwise identically** to
//! a single coordinator — sharding changes where work runs, never what it
//! computes. Runnable without `make artifacts` (stub registry under
//! `target/`). Covers:
//!
//! * all 6 corpus patterns × {inline, handle, seeded-B} × {JSON v2,
//!   binary v3} through a 3-node cluster (admission window ON) vs one
//!   plain single-node server (window OFF): checksum bits equal, full C
//!   (`want_c`) bitwise equal, routing (`algo`) equal. Handles are
//!   compared *behaviorally* — the cluster's owned-id sequence assigns
//!   different handle values by design;
//! * owner-down failover: a replicated operand keeps answering with
//!   bitwise-identical results from a ring successor on both planes; an
//!   unreplicated operand owned by the same stopped node earns the typed
//!   degradation error (`DEGRADED_PREFIX`) on both planes;
//! * cluster `stats` aggregation: the router's snapshot sums the per-node
//!   coordinator gauges exactly (counters, store gauges, batch_hist).
//!
//! Ring-placement unit tests live in `src/coordinator/shard.rs`; the
//! membership-codec and snapshot-aggregation unit tests in
//! `src/serve/cluster.rs` (both run via `cargo test --lib`).

use std::path::PathBuf;
use std::sync::Arc;

use gcoospdm::coordinator::{Coordinator, CoordinatorConfig};
use gcoospdm::gen;
use gcoospdm::json::{self, Value};
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::Registry;
use gcoospdm::serve::{
    Client, Cluster, ClusterConfig, Membership, Server, ServerConfig, DEGRADED_PREFIX,
};

/// Stub registry at n=64, same shape as the wire_differential stub
/// (distinct target dir so parallel test binaries never race on files).
fn runnable_registry() -> Arc<Registry> {
    let dir = PathBuf::from("target/cluster_differential_artifacts");
    std::fs::create_dir_all(&dir).expect("create stub artifact dir");
    std::fs::write(dir.join("stub.hlo.txt"), b"stub").expect("write stub artifact");
    let manifest = r#"{"artifacts": [
        {"name": "gcoo_n64_cap64", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "gcoo_n64_cap512", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "csr_n64_rowcap64", "algo": "csr", "n": 64,
         "params": {"rp": 8, "rowcap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "dense_xla_n64", "algo": "dense_xla", "n": 64,
         "params": {}, "inputs": [], "file": "stub.hlo.txt"}
    ]}"#;
    Arc::new(Registry::from_manifest_json(manifest, dir).expect("stub manifest parses"))
}

/// One plain single-node server: one worker, admission window OFF — the
/// reference deployment every cluster reply is compared against.
fn boot_single() -> (Arc<Coordinator>, String, std::thread::JoinHandle<()>) {
    let cfg = CoordinatorConfig { workers: 1, ..Default::default() };
    let coord = Arc::new(Coordinator::new(runnable_registry(), cfg));
    let server = Server::bind(&ServerConfig::ephemeral(), Arc::clone(&coord)).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        let _ = server.run();
    });
    (coord, addr, handle)
}

/// A 3-node cluster with the admission window ON — together with the
/// window-off single node, one matrix run covers both window modes.
fn boot_cluster(replicate_after: u64) -> Cluster {
    let cfg = ClusterConfig {
        nodes: 3,
        replicas: 2,
        replicate_after,
        node_cfg: CoordinatorConfig {
            workers: 1,
            admission_window_us: 2_000,
            ..Default::default()
        },
        ..Default::default()
    };
    Cluster::start(&cfg, runnable_registry()).expect("cluster starts")
}

fn bits(x: Option<f64>) -> u64 {
    x.expect("reply carries a checksum").to_bits()
}

fn assert_c_bits_equal(got: &Mat, want: &Mat, what: &str) {
    assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{what}: C dims");
    for (i, (g, w)) in got.data.iter().zip(want.data.iter()).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: C[{i}] bitwise");
    }
}

/// The acceptance matrix: 9 patterns × {inline, handle+inline-B,
/// handle+seeded-B} × {JSON, binary}, 3-node window-on cluster vs plain
/// single node, every checksum and every want_c C compared bitwise.
#[test]
fn corpus_bitwise_identical_cluster_vs_single_node() {
    let (_coord, single_addr, single_thread) = boot_single();
    let mut cluster = boot_cluster(3);
    let mut sc = Client::connect(&single_addr).unwrap();
    let mut cc = Client::connect(cluster.router_addr()).unwrap();

    let n = 64usize;
    let mut id = 1_000u64;
    for (pi, pat) in gen::Pattern::ALL.iter().enumerate() {
        let seed = 4_000 + pi as u64;
        let mut rng = Rng::new(seed);
        let a = gen::generate(*pat, n, 0.9, &mut rng);
        let b = Mat::randn(n, n, &mut rng);
        let what = pat.name();

        // Inline, JSON plane.
        let rs = sc.spdm_inline(id, n, &a.data, &b.data, false).unwrap();
        let rc = cc.spdm_inline(id, n, &a.data, &b.data, false).unwrap();
        assert!(rs.ok && rc.ok, "{what}: {:?} / {:?}", rs.error, rc.error);
        assert_eq!(bits(rs.checksum), bits(rc.checksum), "{what}: inline JSON checksum");
        assert_eq!(rs.algo, rc.algo, "{what}: same routing on both deployments");

        // Inline, binary plane with the full C back.
        let (rs, cs) = sc.spdm_inline_bin(id + 1, n, &a.data, &b.data, None, false, true).unwrap();
        let (rc, ccm) = cc.spdm_inline_bin(id + 1, n, &a.data, &b.data, None, false, true).unwrap();
        assert!(rs.ok && rc.ok, "{what}: {:?} / {:?}", rs.error, rc.error);
        assert_eq!(bits(rs.checksum), bits(rc.checksum), "{what}: inline binary checksum");
        assert_c_bits_equal(
            ccm.as_ref().expect("cluster want_c C"),
            cs.as_ref().expect("single want_c C"),
            &format!("{what}: inline"),
        );

        // Register A on both deployments. Handle VALUES differ by design
        // (the cluster's store assigns only ring-owned ids); everything
        // observable through them must not.
        let ps = sc.put_a_inline(id + 2, n, &a.data, "auto").unwrap();
        let pc = cc.put_a_inline(id + 2, n, &a.data, "auto").unwrap();
        assert!(ps.ok && pc.ok, "{what}: {:?} / {:?}", ps.error, pc.error);
        assert_eq!(ps.algo, pc.algo, "{what}: same put_a routing");
        assert_eq!(ps.artifact, pc.artifact, "{what}: same put_a artifact");
        let hs = ps.a_handle.expect("single handle");
        let hc = pc.a_handle.expect("cluster handle");
        // The owned-id sequence makes the handle self-routing: its ring
        // owner is exactly the node whose store registered it.
        let hc_owner = cluster.owner_of(hc) as usize;
        assert!(
            cluster
                .coordinator(hc_owner)
                .store()
                .peek_entry(gcoospdm::coordinator::OperandId(hc))
                .is_some(),
            "{what}: the ring owner's store holds the handle it assigned"
        );

        // Handle + inline B: JSON and binary (full C) planes.
        let rs = sc.spdm_handle(id + 3, hs, &b.data, false).unwrap();
        let rc = cc.spdm_handle(id + 3, hc, &b.data, false).unwrap();
        assert!(rs.ok && rc.ok, "{what}: {:?} / {:?}", rs.error, rc.error);
        assert_eq!(bits(rs.checksum), bits(rc.checksum), "{what}: handle JSON checksum");
        let (rs, cs) = sc.spdm_handle_bin(id + 4, hs, n, &b.data, None, false, true).unwrap();
        let (rc, ccm) = cc.spdm_handle_bin(id + 4, hc, n, &b.data, None, false, true).unwrap();
        assert!(rs.ok && rc.ok, "{what}: {:?} / {:?}", rs.error, rc.error);
        assert_eq!(bits(rs.checksum), bits(rc.checksum), "{what}: handle binary checksum");
        assert_c_bits_equal(
            ccm.as_ref().expect("cluster want_c C"),
            cs.as_ref().expect("single want_c C"),
            &format!("{what}: handle"),
        );

        // Handle + seeded B (the server materializes B from the seed —
        // same dims, same seed, same B on every node).
        let rs = sc.spdm_handle_synthetic_b(id + 5, hs, seed * 7, false).unwrap();
        let rc = cc.spdm_handle_synthetic_b(id + 5, hc, seed * 7, false).unwrap();
        assert!(rs.ok && rc.ok, "{what}: {:?} / {:?}", rs.error, rc.error);
        assert_eq!(bits(rs.checksum), bits(rc.checksum), "{what}: seeded-B JSON checksum");
        let (rs, cs) = sc
            .spdm_handle_synthetic_b_bin(id + 6, hs, seed * 7, None, false, true)
            .unwrap();
        let (rc, ccm) = cc
            .spdm_handle_synthetic_b_bin(id + 6, hc, seed * 7, None, false, true)
            .unwrap();
        assert!(rs.ok && rc.ok, "{what}: {:?} / {:?}", rs.error, rc.error);
        assert_eq!(bits(rs.checksum), bits(rc.checksum), "{what}: seeded-B binary checksum");
        assert_c_bits_equal(
            ccm.as_ref().expect("cluster want_c C"),
            cs.as_ref().expect("single want_c C"),
            &format!("{what}: seeded-B"),
        );

        // Drop on both; a re-use afterwards earns the same typed error.
        let ds = sc.drop_a(id + 7, hs).unwrap();
        let dc = cc.drop_a(id + 7, hc).unwrap();
        assert!(ds.ok && dc.ok, "{what}: drop: {:?} / {:?}", ds.error, dc.error);
        let rs = sc.spdm_handle(id + 8, hs, &b.data, false).unwrap();
        let rc = cc.spdm_handle(id + 8, hc, &b.data, false).unwrap();
        assert!(!rs.ok && !rc.ok, "{what}: dropped handles must not serve");
        assert!(
            rs.error.as_deref().unwrap_or("").contains("unknown operand handle")
                && rc.error.as_deref().unwrap_or("").contains("unknown operand handle"),
            "{what}: same typed error after drop: {:?} / {:?}",
            rs.error,
            rc.error
        );

        id += 10;
    }

    // Window coverage sanity: the cluster really ran with the admission
    // window on (its nodes saw windowed batches) and the single node ran
    // with it off.
    let agg = cluster.snapshot();
    assert!(
        agg.window_hits + agg.window_timeouts > 0,
        "cluster nodes must have exercised the admission window"
    );
    assert_eq!(_coord.snapshot().window_hits, 0, "single node runs window-off");

    let _ = sc.shutdown(9_999);
    let _ = single_thread.join();
    cluster.shutdown();
}

/// Owner-down failover: replicated operands keep answering bitwise
/// identically from a ring successor; an unreplicated operand owned by
/// the same stopped node degrades with the typed error — on both planes.
#[test]
fn owner_down_failover_is_bitwise_and_unreplicated_degrades_typed() {
    // Huge replicate_after: replication happens only when the test says so.
    let mut cluster = boot_cluster(u64::MAX);
    let mut client = Client::connect(cluster.router_addr()).unwrap();

    let n = 64usize;
    let mut rng = Rng::new(77);
    let a1 = gen::generate(gen::Pattern::ALL[0], n, 0.9, &mut rng);
    let b = Mat::randn(n, n, &mut rng);

    let p1 = client.put_a_inline(1, n, &a1.data, "auto").unwrap();
    assert!(p1.ok, "{:?}", p1.error);
    let h1 = p1.a_handle.unwrap();
    let owner = cluster.owner_of(h1);

    // A second operand owned by the same node (so stopping that node
    // takes both down): scan seeds until content routing lands there.
    let mut h2 = None;
    for seed in 100..200u64 {
        let mut rng = Rng::new(seed);
        let a2 = gen::generate(gen::Pattern::ALL[1], n, 0.9, &mut rng);
        let p2 = client.put_a_inline(seed, n, &a2.data, "auto").unwrap();
        assert!(p2.ok, "{:?}", p2.error);
        let h = p2.a_handle.unwrap();
        if cluster.owner_of(h) == owner {
            h2 = Some(h);
            break;
        }
        let _ = client.drop_a(seed + 1_000, h);
    }
    let h2 = h2.expect("some seed lands on the same owner within 100 tries");

    // Baseline bits with the owner up.
    let base_json = client.spdm_handle(10, h1, &b.data, false).unwrap();
    assert!(base_json.ok, "{:?}", base_json.error);
    let (base_bin, base_c) = client.spdm_handle_bin(11, h1, n, &b.data, None, false, true).unwrap();
    assert!(base_bin.ok, "{:?}", base_bin.error);
    let base_c = base_c.expect("baseline C");

    // Replicate h1 (and only h1) to its ring successor, then kill the
    // owner's serving endpoint.
    let installed = cluster.replicate(h1).expect("replication succeeds");
    assert_eq!(installed, 1, "one fresh replica on the 2-replica ring");
    let chain = cluster.replica_chain(h1);
    assert_eq!(chain[0], owner);
    assert!(
        cluster.coordinator(chain[1] as usize).store().peek_entry(
            gcoospdm::coordinator::OperandId(h1)
        ).is_some(),
        "replica node holds the copy"
    );
    cluster.stop_node(owner as usize);

    // Replicated operand: served from the successor, bitwise identical,
    // both planes — on the SAME client connection (its cached backend
    // route to the dead owner must fail over) and on a fresh one.
    for c in [&mut client, &mut Client::connect(cluster.router_addr()).unwrap()] {
        let r = c.spdm_handle(20, h1, &b.data, false).unwrap();
        assert!(r.ok, "failover JSON serves: {:?}", r.error);
        assert_eq!(bits(r.checksum), bits(base_json.checksum), "failover JSON checksum bits");
        let (r, cm) = c.spdm_handle_bin(21, h1, n, &b.data, None, false, true).unwrap();
        assert!(r.ok, "failover binary serves: {:?}", r.error);
        assert_eq!(bits(r.checksum), bits(base_bin.checksum), "failover binary checksum bits");
        assert_c_bits_equal(cm.as_ref().expect("failover C"), &base_c, "failover");

        // Unreplicated operand on the stopped owner: typed degradation
        // error, not a hang, not a silent wrong answer — both planes.
        let r = c.spdm_handle(22, h2, &b.data, false).unwrap();
        assert!(!r.ok, "unreplicated operand must not serve");
        let err = r.error.unwrap_or_default();
        assert!(err.starts_with(DEGRADED_PREFIX), "typed degradation (JSON): {err}");
        let (r, _) = c.spdm_handle_bin(23, h2, n, &b.data, None, false, false).unwrap();
        assert!(!r.ok, "unreplicated operand must not serve on the binary plane");
        let err = r.error.unwrap_or_default();
        assert!(err.starts_with(DEGRADED_PREFIX), "typed degradation (binary): {err}");
    }

    cluster.shutdown();
}

/// Cluster `stats` sums the per-node gauges exactly: every counter in the
/// router's aggregated snapshot equals the sum over the in-process node
/// coordinators, taken on quiesced traffic.
#[test]
fn cluster_stats_aggregation_sums_node_gauges_exactly() {
    let mut cluster = boot_cluster(2);
    let mut client = Client::connect(cluster.router_addr()).unwrap();

    let n = 64usize;
    let mut rng = Rng::new(5);
    let a = gen::generate(gen::Pattern::ALL[2], n, 0.9, &mut rng);
    let b = Mat::randn(n, n, &mut rng);
    let p = client.put_a_inline(1, n, &a.data, "auto").unwrap();
    assert!(p.ok, "{:?}", p.error);
    let h = p.a_handle.unwrap();
    for i in 0..5u64 {
        let r = client.spdm_handle(10 + i, h, &b.data, false).unwrap();
        assert!(r.ok, "{:?}", r.error);
    }
    // Spread some inline traffic (content keys land where they land) and
    // one error so the error counter is non-trivial somewhere.
    for i in 0..4u64 {
        let mut rng = Rng::new(50 + i);
        let ai = gen::generate(gen::Pattern::ALL[i as usize % gen::Pattern::ALL.len()], n, 0.9, &mut rng);
        let bi = Mat::randn(n, n, &mut rng);
        let r = client.spdm_inline(30 + i, n, &ai.data, &bi.data, false).unwrap();
        assert!(r.ok, "{:?}", r.error);
    }
    let r = client.drop_a(90, 999_999).unwrap();
    assert!(!r.ok, "bogus drop must fail");

    // All traffic above is run_sync — replies arrived, so every node's
    // metrics are settled. Compare the wire-aggregated stats to the sum
    // of the in-process snapshots.
    let reply = client.stats(100).unwrap();
    assert!(reply.ok, "{:?}", reply.error);
    let doc = json::parse(&reply.metrics.expect("stats carries metrics")).unwrap();
    let agg = cluster.snapshot();
    let sum_of = |f: fn(&gcoospdm::coordinator::MetricsSnapshot) -> u64| -> u64 {
        (0..cluster.node_count()).map(|i| f(&cluster.coordinator(i).snapshot())).sum()
    };
    let field = |k: &str| -> u64 {
        doc.get(k).and_then(Value::as_u64).unwrap_or_else(|| panic!("stats field {k}"))
    };
    for (name, by_node, via_wire) in [
        ("submitted", sum_of(|s| s.submitted), field("submitted")),
        ("completed", sum_of(|s| s.completed), field("completed")),
        ("errors", sum_of(|s| s.errors), field("errors")),
        ("verify_failures", sum_of(|s| s.verify_failures), field("verify_failures")),
        ("conversions_total", sum_of(|s| s.conversions_total), field("conversions_total")),
        ("store_entries", sum_of(|s| s.store_entries), field("store_entries")),
        ("store_bytes", sum_of(|s| s.store_bytes), field("store_bytes")),
        ("store_budget_bytes", sum_of(|s| s.store_budget_bytes), field("store_budget_bytes")),
        ("store_hits", sum_of(|s| s.store_hits), field("store_hits")),
        ("store_misses", sum_of(|s| s.store_misses), field("store_misses")),
        ("store_evictions", sum_of(|s| s.store_evictions), field("store_evictions")),
        ("window_hits", sum_of(|s| s.window_hits), field("window_hits")),
        ("window_timeouts", sum_of(|s| s.window_timeouts), field("window_timeouts")),
    ] {
        assert_eq!(via_wire, by_node, "stats field {name} must sum node gauges exactly");
    }
    // The aggregated snapshot the router serves is the same function the
    // Cluster accessor exposes.
    assert_eq!(field("submitted"), agg.submitted);
    assert_eq!(field("store_hits"), agg.store_hits);
    let hist: u64 = doc
        .get("batch_hist")
        .and_then(Value::as_arr)
        .expect("batch_hist array")
        .iter()
        .filter_map(Value::as_u64)
        .sum();
    let hist_by_node: u64 =
        (0..cluster.node_count()).map(|i| cluster.coordinator(i).snapshot().batch_hist.iter().sum::<u64>()).sum();
    assert_eq!(hist, hist_by_node, "batch_hist sums bucket-wise");

    cluster.shutdown();
}

/// Cluster-aware addressing: the membership doc round-trips over its
/// codec, and `connect_any` dials through dead addresses to a live one.
#[test]
fn membership_doc_and_connect_any_reach_the_cluster() {
    let mut cluster = boot_cluster(3);
    let doc = cluster.membership().to_json();
    let back = Membership::from_json(&doc).expect("membership round-trips");
    assert_eq!(&back, cluster.membership());
    assert_eq!(back.nodes.len(), 3);

    // Router first, node addresses as fallback — and a dead address in
    // front must not prevent the connect.
    let mut addrs = vec!["127.0.0.1:1".to_string(), cluster.router_addr().to_string()];
    addrs.extend(back.nodes.iter().map(|n| n.addr.clone()));
    let mut client = Client::connect_any(&addrs).expect("connect_any finds the router");
    let r = client.ping(1).unwrap();
    assert!(r.ok);
    let r = client.ping_bin(2).unwrap();
    assert!(r.ok, "both planes answer through connect_any");

    assert!(Client::connect_any(&["127.0.0.1:1"]).is_err(), "all-dead list errors");
    cluster.shutdown();
}
