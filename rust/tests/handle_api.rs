//! Operand-handle API v2 lockdown, runnable without `make artifacts` (stub
//! registry under `target/`, the engine only needs artifact files to
//! exist):
//!
//! * protocol v2 round trips against a live server — `put_a` (inline +
//!   synthetic, routing introspection in the reply), `spdm` by handle
//!   (inline and synthetic B), `drop_a`, `list_a`, unknown-handle and
//!   use-after-drop errors;
//! * the differential: handle-path results **bitwise equal** to the
//!   inline path across every corpus pattern × both sparse algorithms
//!   (and the dense fallback), matching and padded sizes;
//! * EO amortization through `/stats`: conversions on repeated same-A
//!   handle traffic stay constant (one per handle) as request count grows.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use gcoospdm::coordinator::{
    process_batch_ws, process_one_ws, Algo, BatchJob, Coordinator, CoordinatorConfig,
    OperandId, OperandStore, SpdmRequest, SubmitError, Workspace,
};
use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::{Engine, Registry};
use gcoospdm::serve::{Client, Server, ServerConfig};

/// Stub registry at n=64: two gcoo capacities, a csr variant, the dense
/// fallback — same shape as the batch-differential stub.
fn runnable_registry() -> Registry {
    let dir = PathBuf::from("target/handle_api_artifacts");
    std::fs::create_dir_all(&dir).expect("create stub artifact dir");
    std::fs::write(dir.join("stub.hlo.txt"), b"stub").expect("write stub artifact");
    let manifest = r#"{"artifacts": [
        {"name": "gcoo_n64_cap64", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "gcoo_n64_cap512", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "csr_n64_rowcap64", "algo": "csr", "n": 64,
         "params": {"rp": 8, "rowcap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "dense_xla_n64", "algo": "dense_xla", "n": 64,
         "params": {}, "inputs": [], "file": "stub.hlo.txt"}
    ]}"#;
    Registry::from_manifest_json(manifest, dir).expect("stub manifest parses")
}

fn boot() -> (Arc<Coordinator>, String, std::thread::JoinHandle<()>) {
    let coord = Arc::new(Coordinator::new(
        Arc::new(runnable_registry()),
        CoordinatorConfig { workers: 1, ..Default::default() },
    ));
    let server = Server::bind(&ServerConfig::ephemeral(), Arc::clone(&coord)).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        let _ = server.run();
    });
    (coord, addr, handle)
}

/// The full v2 session: register → introspect → multiply by reference →
/// list → dedup → drop → use-after-drop, with v1 traffic interleaved
/// unchanged on the same connection.
#[test]
fn protocol_v2_round_trip_session() {
    let (_coord, addr, server) = boot();
    let mut client = Client::connect(&addr).unwrap();

    // Register a 64×64 identity inline: the reply exposes the resolved
    // routing (handle, algo, artifact, n_exec, reason, registration EO).
    let mut eye = vec![0.0f32; 64 * 64];
    for i in 0..64 {
        eye[i * 64 + i] = 1.0;
    }
    let r = client.put_a_inline(1, 64, &eye, "gcoo").unwrap();
    assert!(r.ok, "{:?}", r.error);
    let h = r.a_handle.expect("put_a reply carries a_handle");
    assert_eq!(r.algo.as_deref(), Some("gcoo"));
    assert_eq!(r.n_exec, Some(64));
    assert_eq!(r.reason.as_deref(), Some("hint"));
    assert!(r.artifact.as_deref().unwrap_or("").starts_with("gcoo_n64"));
    assert!(r.convert_ms.unwrap() >= 0.0);

    // Multiply by reference, inline B: identity A ⇒ C = B.
    let b: Vec<f32> = (0..64 * 64).map(|i| (i % 7) as f32 * 0.5).collect();
    let r = client.spdm_handle(2, h, &b, true).unwrap();
    assert!(r.ok, "{:?}", r.error);
    assert_eq!(r.verified, Some(true));
    assert_eq!(r.a_handle, Some(h), "handle spdm replies echo the handle");
    assert_eq!(r.convert_ms, Some(0.0), "handle path pays no conversion");
    let want: f64 = b.iter().map(|x| *x as f64).sum();
    assert!((r.checksum.unwrap() - want).abs() < 1e-3, "identity A ⇒ checksum = ΣB");

    // Synthetic B by seed: deterministic per seed.
    let c1 = client.spdm_handle_synthetic_b(3, h, 7, true).unwrap();
    let c2 = client.spdm_handle_synthetic_b(4, h, 7, false).unwrap();
    assert!(c1.ok && c2.ok);
    assert_eq!(c1.verified, Some(true));
    assert_eq!(c1.checksum, c2.checksum);

    // list_a shows the entry with its routing summary.
    let r = client.list_a(5).unwrap();
    assert!(r.ok);
    let rows = r.handles.expect("list_a reply carries rows");
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].a_handle, h);
    assert_eq!((rows[0].n, rows[0].nnz), (64, 64));
    assert_eq!(rows[0].algo, "gcoo");
    assert!(rows[0].bytes > 0);

    // Re-registering identical content+hint dedups to the same handle.
    let r = client.put_a_inline(6, 64, &eye, "gcoo").unwrap();
    assert!(r.ok);
    assert_eq!(r.a_handle, Some(h), "same content + hint must dedup");

    // Wrong-size inline B on the handle path errors cleanly.
    let r = client.spdm_handle(7, h, &[1.0, 2.0], false).unwrap();
    assert!(!r.ok);
    assert!(r.error.unwrap().contains("inline b size"));

    // v1 traffic still flows unchanged on the same connection.
    let r = client.spdm_synthetic(8, 64, 0.99, "uniform", 3, "auto", true).unwrap();
    assert!(r.ok, "{:?}", r.error);
    assert_eq!(r.verified, Some(true));

    // drop_a; use-after-drop and double-drop fail with a clear error.
    let r = client.drop_a(9, h).unwrap();
    assert!(r.ok);
    let r = client.spdm_handle_synthetic_b(10, h, 1, false).unwrap();
    assert!(!r.ok);
    assert!(r.error.unwrap().contains("unknown operand handle"));
    let r = client.drop_a(11, h).unwrap();
    assert!(!r.ok);
    // Unknown handle on a never-registered id.
    let r = client.spdm_handle(12, 777, &b, false).unwrap();
    assert!(!r.ok);
    assert!(r.error.unwrap().contains("unknown operand handle"));
    let r = client.list_a(13).unwrap();
    assert_eq!(r.handles, Some(vec![]));

    client.shutdown(99).unwrap();
    server.join().unwrap();
}

/// The acceptance differential: for every corpus pattern × both sparse
/// algorithms (plus the dense fallback), matching (n=64) and padded
/// (n=60) sizes, multiply-by-handle must be **bitwise identical** to the
/// inline path — same algo, artifact, n_exec, verification verdict, and
/// result bytes — while performing zero per-request conversions.
#[test]
fn handle_path_bitwise_equals_inline_path() {
    let coord = Coordinator::new(
        Arc::new(runnable_registry()),
        CoordinatorConfig { workers: 1, ..Default::default() },
    );
    let mut rng = Rng::new(0xAB1E);
    for (pi, pattern) in gen::Pattern::ALL.iter().enumerate() {
        let n = if pi % 2 == 0 { 64 } else { 60 };
        let a = gen::generate(*pattern, n, 0.95, &mut rng);
        for algo in [Some(Algo::Gcoo), Some(Algo::Csr), None] {
            let entry = coord.put_a(a.clone(), algo).expect("put_a");
            assert_eq!(entry.a.rows, n);
            for i in 0..3u64 {
                let b = Mat::randn(n, n, &mut rng);
                let mut hreq = SpdmRequest::for_handle(1000 + i, entry.handle, b.clone());
                hreq.algo_hint = algo;
                hreq.verify = i == 0;
                let hresp = coord.run_sync(hreq);
                let mut ireq = SpdmRequest::new(2000 + i, a.clone(), b.clone());
                ireq.algo_hint = algo;
                ireq.verify = i == 0;
                let iresp = coord.run_sync(ireq);
                let ctx = format!("{}/{:?}/n{}/b{}", pattern.name(), algo, n, i);
                assert!(hresp.ok(), "{ctx} handle: {:?}", hresp.error);
                assert!(iresp.ok(), "{ctx} inline: {:?}", iresp.error);
                assert_eq!(hresp.algo, iresp.algo, "{ctx} algo");
                assert_eq!(hresp.artifact, iresp.artifact, "{ctx} artifact");
                assert_eq!(hresp.n_exec, iresp.n_exec, "{ctx} n_exec");
                assert_eq!(hresp.verified, iresp.verified, "{ctx} verdicts");
                if i == 0 {
                    assert_eq!(hresp.verified, Some(true), "{ctx} oracle");
                }
                assert!(
                    hresp.c == iresp.c,
                    "{ctx}: handle C is not bitwise identical to inline C"
                );
                assert_eq!(hresp.conversions, 0, "{ctx}: handle path must not convert");
                assert_eq!(hresp.convert_s, 0.0, "{ctx}: handle path bills no EO");
            }
        }
    }
    coord.shutdown();
}

/// A hint the cached entry cannot serve falls back to the
/// convert-per-request path over the entry's dense A — still correct,
/// still bitwise-equal to inline under the same hint.
#[test]
fn incompatible_hint_falls_back_correctly() {
    let coord = Coordinator::new(
        Arc::new(runnable_registry()),
        CoordinatorConfig { workers: 1, ..Default::default() },
    );
    let mut rng = Rng::new(0xFA11);
    let a = gen::uniform(64, 0.97, &mut rng);
    let entry = coord.put_a(a.clone(), Some(Algo::Gcoo)).expect("put_a");
    let b = Mat::randn(64, 64, &mut rng);
    // Request csr against a gcoo-registered operand.
    let mut hreq = SpdmRequest::for_handle(1, entry.handle, b.clone());
    hreq.algo_hint = Some(Algo::Csr);
    hreq.verify = true;
    let hresp = coord.run_sync(hreq);
    assert!(hresp.ok(), "{:?}", hresp.error);
    assert_eq!(hresp.algo, Algo::Csr, "the request hint wins");
    assert_eq!(hresp.verified, Some(true));
    assert_eq!(hresp.conversions, 1, "fallback converts for this request");
    let mut ireq = SpdmRequest::new(2, a.clone(), b.clone());
    ireq.algo_hint = Some(Algo::Csr);
    let iresp = coord.run_sync(ireq);
    assert!(hresp.c == iresp.c, "fallback still bitwise-matches inline");
    coord.shutdown();
}

/// Mixed handle/inline fusion respects both routing contracts: an entry
/// registered under a conflicting hint must not reroute unhinted inline
/// riders (they keep selector routing whether or not they co-batch, and
/// their bytes are identical to a solo run), while a hint-compatible
/// entry still serves the whole mixed unit from cache with zero
/// conversions.
#[test]
fn mixed_batch_keeps_inline_routing_deterministic() {
    let reg = runnable_registry();
    let cfg = CoordinatorConfig::default();
    let engine = Engine::new().unwrap();
    let mut ws = Workspace::new();
    let store = OperandStore::new(64 << 20);
    let mut rng = Rng::new(0x313D);
    let a = gen::uniform(64, 0.99, &mut rng); // unhinted selector routing: gcoo
    let b1 = Mat::randn(64, 64, &mut rng);
    let b2 = Mat::randn(64, 64, &mut rng);
    let ireq = SpdmRequest::new(2, a.clone(), b2.clone());
    let solo = process_one_ws(&engine, &mut ws, &reg, &cfg, &ireq, None, Instant::now());
    assert_eq!(solo.algo, Algo::Gcoo);

    // Conflicting case: A registered under a csr hint, both requests
    // unhinted. The handle job keeps the registered routing, the inline
    // rider keeps selector routing — no cross-contamination.
    let (entry, _) = store.register(a.clone(), Some(Algo::Csr), &reg, &cfg).unwrap();
    let mut hreq = SpdmRequest::for_handle(1, entry.handle, b1.clone());
    hreq.a_sig = entry.sig; // what Coordinator::submit does on resolve
    let jobs = [
        BatchJob { req: &hreq, entry: Some(&*entry), enqueued: Instant::now() },
        BatchJob::inline(&ireq, Instant::now()),
    ];
    let resps = process_batch_ws(&engine, &mut ws, &reg, &cfg, &jobs);
    assert_eq!(resps[0].algo, Algo::Csr, "handle request keeps the registered routing");
    assert_eq!(resps[0].conversions, 0, "…served from cache");
    assert_eq!(resps[1].algo, Algo::Gcoo, "inline rider keeps selector routing");
    assert!(
        resps[1].c == solo.c,
        "inline result must not depend on co-batched handle traffic"
    );

    // Compatible case: unhinted registration — the cached entry serves
    // the whole mixed unit, zero conversions, bitwise-stable bytes.
    let (e2, _) = store.register(a.clone(), None, &reg, &cfg).unwrap();
    let mut h2 = SpdmRequest::for_handle(3, e2.handle, b1.clone());
    h2.a_sig = e2.sig;
    let i2 = SpdmRequest::new(4, a.clone(), b2.clone());
    let jobs = [
        BatchJob { req: &h2, entry: Some(&*e2), enqueued: Instant::now() },
        BatchJob::inline(&i2, Instant::now()),
    ];
    let resps = process_batch_ws(&engine, &mut ws, &reg, &cfg, &jobs);
    assert!(resps.iter().all(|r| r.ok() && r.algo == Algo::Gcoo));
    assert_eq!(
        resps.iter().map(|r| r.conversions).sum::<u64>(),
        0,
        "a hint-compatible cached entry serves the mixed batch without converting"
    );
    assert!(resps[1].c == solo.c, "fused-from-cache inline result still bitwise stable");
}

/// Submit-level handle failures are typed, and `run_sync` maps them to
/// failed responses (which serve turns into JSON errors).
#[test]
fn unknown_handle_fails_fast_at_submit() {
    let coord = Coordinator::new(
        Arc::new(runnable_registry()),
        CoordinatorConfig { workers: 1, ..Default::default() },
    );
    let ghost = OperandId(4242);
    let req = SpdmRequest::for_handle(1, ghost, Mat::zeros(64, 64));
    match coord.submit(req) {
        Err(SubmitError::UnknownHandle(h)) => assert_eq!(h, ghost),
        other => panic!("expected UnknownHandle, got {other:?}"),
    }
    let resp = coord.run_sync(SpdmRequest::for_handle(2, ghost, Mat::zeros(64, 64)));
    assert!(!resp.ok());
    assert!(resp.error.unwrap().contains("unknown operand handle"));
    // Dropped mid-session: in-flight submit already resolved its pin, so
    // only *later* submits fail.
    let mut rng = Rng::new(3);
    let a = gen::uniform(64, 0.99, &mut rng);
    let entry = coord.put_a(a, None).unwrap();
    assert!(coord.drop_a(entry.handle));
    assert!(matches!(
        coord.submit(SpdmRequest::for_handle(3, entry.handle, Mat::zeros(64, 64))),
        Err(SubmitError::UnknownHandle(_))
    ));
    coord.shutdown();
}

/// The acceptance EO criterion through the wire: `/stats` shows
/// `conversions_total` staying constant (one per registered handle) while
/// handle request counts grow — and the store gauges surface.
#[test]
fn stats_show_conversions_constant_per_handle() {
    let (_coord, addr, server) = boot();
    let mut client = Client::connect(&addr).unwrap();

    let r = client.put_a_synthetic(1, 64, 0.99, "uniform", 11, "gcoo").unwrap();
    assert!(r.ok, "{:?}", r.error);
    let h = r.a_handle.unwrap();

    let parse_stats = |resp: gcoospdm::serve::Response| {
        gcoospdm::json::parse(&resp.metrics.expect("stats payload")).expect("valid JSON")
    };
    let conversions = |v: &gcoospdm::json::Value| {
        v.get("conversions_total").unwrap().as_u64().unwrap()
    };

    for i in 0..4u64 {
        let r = client.spdm_handle_synthetic_b(10 + i, h, i, true).unwrap();
        assert!(r.ok, "{:?}", r.error);
        assert_eq!(r.verified, Some(true));
    }
    let v = parse_stats(client.stats(50).unwrap());
    assert_eq!(conversions(&v), 1, "4 handle requests, still one conversion (the put_a)");
    assert_eq!(v.get("store_entries").unwrap().as_u64(), Some(1));
    assert!(v.get("store_hits").unwrap().as_u64().unwrap() >= 4);
    assert!(v.get("store_bytes").unwrap().as_u64().unwrap() > 0);

    // Grow the request count: conversions stay one per handle.
    for i in 0..6u64 {
        let r = client.spdm_handle_synthetic_b(20 + i, h, 100 + i, false).unwrap();
        assert!(r.ok, "{:?}", r.error);
    }
    let v = parse_stats(client.stats(51).unwrap());
    assert_eq!(conversions(&v), 1, "10 handle requests, still one conversion");

    // A second handle adds exactly one more conversion.
    let r = client.put_a_synthetic(60, 64, 0.99, "banded", 12, "gcoo").unwrap();
    assert!(r.ok, "{:?}", r.error);
    let h2 = r.a_handle.unwrap();
    assert_ne!(h2, h);
    for i in 0..3u64 {
        let r = client.spdm_handle_synthetic_b(70 + i, h2, i, false).unwrap();
        assert!(r.ok, "{:?}", r.error);
    }
    let v = parse_stats(client.stats(52).unwrap());
    assert_eq!(conversions(&v), 2, "one conversion per registered handle");

    // Inline traffic, by contrast, converts per request.
    for i in 0..2u64 {
        let r = client.spdm_synthetic(80 + i, 64, 0.99, "uniform", 50 + i, "gcoo", false).unwrap();
        assert!(r.ok, "{:?}", r.error);
    }
    let v = parse_stats(client.stats(53).unwrap());
    assert_eq!(conversions(&v), 4, "each inline request pays its own conversion");

    client.shutdown(99).unwrap();
    server.join().unwrap();
}

/// Handle requests batch and fuse: several in-flight requests against one
/// handle dequeue as a fused batch (operand-keyed affinity), answer with
/// the oracle-verified product, and still perform zero conversions.
#[test]
fn handle_traffic_fuses_without_converting() {
    let coord = Coordinator::new(
        Arc::new(runnable_registry()),
        CoordinatorConfig { workers: 1, ..Default::default() },
    );
    let mut rng = Rng::new(0xF05E);
    let a = gen::uniform(64, 0.97, &mut rng);
    let entry = coord.put_a(a.clone(), Some(Algo::Gcoo)).unwrap();
    let mut receivers = Vec::new();
    for i in 0..10u64 {
        let mut req = SpdmRequest::for_handle(i, entry.handle, Mat::randn(64, 64, &mut rng));
        req.verify = true;
        receivers.push(coord.submit(req).expect("queue open"));
    }
    let mut total_conversions = 0;
    for rx in receivers {
        let resp = rx.recv().expect("reply delivered");
        assert!(resp.ok(), "{:?}", resp.error);
        assert_eq!(resp.verified, Some(true));
        total_conversions += resp.conversions;
    }
    assert_eq!(total_conversions, 0, "handle traffic never converts, fused or not");
    let snap = coord.snapshot();
    assert_eq!(snap.completed, 10);
    assert_eq!(snap.conversions_total, 1, "only the put_a converted");
    assert_eq!(
        snap.batched_jobs(),
        snap.completed + snap.errors,
        "batch histogram still balances under handle traffic"
    );
    coord.shutdown();
}
