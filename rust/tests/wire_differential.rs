//! Cross-protocol differential: the binary v3 plane and the JSON v2 plane
//! must produce **bitwise-identical results** for identical requests —
//! encoding may change wire cost, never C. Runnable without `make
//! artifacts` (stub registry under `target/`; the engine only needs the
//! artifact files to exist). Covers:
//!
//! * all 6 corpus patterns × {inline, handle} × {JSON v2, binary v3}:
//!   checksum bits equal across planes, and the binary plane's full-C
//!   reply (`want_c`) bitwise equal to the same request run through the
//!   local pipeline (`process_one_ws`);
//! * non-finite-float validation parity on the binary plane: a crafted
//!   raw-f32 NaN payload earns a typed error frame with the request's id;
//! * garbage magic / bad version on a live connection: typed error frame,
//!   then close;
//! * admission-window differential at the coordinator level: the same
//!   workload through window=0 and window-on coordinators yields bitwise
//!   identical checksums (timing changes batching choices, never
//!   results), the window-on coordinator's batches all carry a window
//!   outcome (hits + timeouts = total batches), and the window=0
//!   coordinator's window counters stay zero.
//!
//! Frame-codec round-trip/truncation/oversize/garbage property tests live
//! next to the codec in `src/serve/protocol.rs` (run via
//! `cargo test --lib serve::protocol`); the scripted-clock fuse-vs-timeout
//! unit tests live in `src/coordinator/queue.rs`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use gcoospdm::coordinator::{
    process_one_ws, Coordinator, CoordinatorConfig, SpdmRequest, Workspace,
};
use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::{Engine, Registry};
use gcoospdm::serve::{frame, Client, Server, ServerConfig};

/// Stub registry at n=64, same shape as the handle_api stub (distinct
/// target dir so parallel test binaries never race on the files).
fn runnable_registry() -> Registry {
    let dir = PathBuf::from("target/wire_differential_artifacts");
    std::fs::create_dir_all(&dir).expect("create stub artifact dir");
    std::fs::write(dir.join("stub.hlo.txt"), b"stub").expect("write stub artifact");
    let manifest = r#"{"artifacts": [
        {"name": "gcoo_n64_cap64", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "gcoo_n64_cap512", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "csr_n64_rowcap64", "algo": "csr", "n": 64,
         "params": {"rp": 8, "rowcap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "dense_xla_n64", "algo": "dense_xla", "n": 64,
         "params": {}, "inputs": [], "file": "stub.hlo.txt"}
    ]}"#;
    Registry::from_manifest_json(manifest, dir).expect("stub manifest parses")
}

fn boot(cfg: CoordinatorConfig) -> (Arc<Coordinator>, String, std::thread::JoinHandle<()>) {
    let coord = Arc::new(Coordinator::new(Arc::new(runnable_registry()), cfg));
    let server = Server::bind(&ServerConfig::ephemeral(), Arc::clone(&coord)).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        let _ = server.run();
    });
    (coord, addr, handle)
}

fn one_worker() -> CoordinatorConfig {
    CoordinatorConfig { workers: 1, ..Default::default() }
}

/// Bit-faithful f64 comparison — the JSON plane renders checksums with
/// Rust's shortest-round-trip float formatting, so even across a text
/// encoding the bits must survive exactly.
fn bits(x: Option<f64>) -> u64 {
    x.expect("reply carries a checksum").to_bits()
}

/// The acceptance differential: every corpus pattern × {inline, handle} ×
/// {JSON v2, binary v3}. The binary plane's full-C reply is the ground
/// truth the checksums are checked against: C from the wire must be
/// bitwise equal to the same request run through the local pipeline.
#[test]
fn corpus_inline_and_handle_bitwise_identical_across_planes() {
    let (_coord, addr, server) = boot(one_worker());
    let mut client = Client::connect(&addr).unwrap();

    // Local pipeline for the expected C (same registry shape + config).
    let registry = runnable_registry();
    let engine = Engine::new().expect("local engine");
    let mut ws = Workspace::new();
    let cfg = one_worker();

    let n = 64usize;
    let mut id = 100u64;
    for (pi, pat) in gen::Pattern::ALL.iter().enumerate() {
        let seed = 1000 + pi as u64;
        let mut rng = Rng::new(seed);
        let a = gen::generate(*pat, n, 0.9, &mut rng);
        let b = Mat::randn(n, n, &mut rng);

        let expected = process_one_ws(
            &engine,
            &mut ws,
            &registry,
            &cfg,
            &SpdmRequest::new(0, a.clone(), b.clone()),
            None,
            Instant::now(),
        );
        assert!(expected.error.is_none(), "{:?}", expected.error);
        let expected_c = expected.c.as_ref().expect("local pipeline returns C");

        // Inline: JSON v2 vs binary v3 (with the full C back).
        let rj = client.spdm_inline(id, n, &a.data, &b.data, false).unwrap();
        assert!(rj.ok, "{}: {:?}", pat.name(), rj.error);
        let (rb, c_bin) =
            client.spdm_inline_bin(id + 1, n, &a.data, &b.data, None, false, true).unwrap();
        assert!(rb.ok, "{}: {:?}", pat.name(), rb.error);
        assert_eq!(
            bits(rj.checksum),
            bits(rb.checksum),
            "{}: inline checksum must be bitwise equal across planes",
            pat.name()
        );
        assert_eq!(rj.algo, rb.algo, "{}: same routing on both planes", pat.name());
        let c_bin = c_bin.expect("want_c reply carries C");
        assert_eq!(
            (c_bin.rows, c_bin.cols),
            (expected_c.rows, expected_c.cols),
            "{}: C dims",
            pat.name()
        );
        for (i, (got, want)) in c_bin.data.iter().zip(expected_c.data.iter()).enumerate() {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "{}: C[{}] from the wire must be bitwise equal to the local pipeline",
                pat.name(),
                i
            );
        }

        // Handle: register A on the JSON plane, multiply by reference on
        // both planes (inline B and seeded B), checksums bitwise equal.
        let rp = client.put_a_inline(id + 2, n, &a.data, "auto").unwrap();
        assert!(rp.ok, "{}: {:?}", pat.name(), rp.error);
        let h = rp.a_handle.unwrap();
        let hj = client.spdm_handle(id + 3, h, &b.data, false).unwrap();
        assert!(hj.ok, "{}: {:?}", pat.name(), hj.error);
        let (hb, c_hb) =
            client.spdm_handle_bin(id + 4, h, n, &b.data, None, false, true).unwrap();
        assert!(hb.ok, "{}: {:?}", pat.name(), hb.error);
        assert_eq!(
            bits(hj.checksum),
            bits(hb.checksum),
            "{}: handle checksum must be bitwise equal across planes",
            pat.name()
        );
        assert_eq!(hb.a_handle, Some(h), "{}: binary reply echoes the handle", pat.name());
        let c_hb = c_hb.expect("want_c reply carries C");
        for (got, want) in c_hb.data.iter().zip(c_bin.data.iter()) {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "{}: handle-path C must be bitwise equal to inline C",
                pat.name()
            );
        }

        // Seeded B: both planes generate B server-side from the same seed.
        let sj = client.spdm_handle_synthetic_b(id + 5, h, seed + 7, false).unwrap();
        let (sb, _) = client
            .spdm_handle_synthetic_b_bin(id + 6, h, seed + 7, None, false, false)
            .unwrap();
        assert!(sj.ok && sb.ok);
        assert_eq!(
            bits(sj.checksum),
            bits(sb.checksum),
            "{}: seeded-B checksum must be bitwise equal across planes",
            pat.name()
        );

        // Clean up the handle so each pattern registers fresh.
        assert!(client.drop_a(id + 7, h).unwrap().ok);
        id += 10;
    }

    client.shutdown(9_999).unwrap();
    server.join().unwrap();
}

/// Binary `put_a` + binary ping round-trip against a live server, and the
/// two planes agree on the registered handle.
#[test]
fn binary_put_a_and_ping_round_trip() {
    let (_coord, addr, server) = boot(one_worker());
    let mut client = Client::connect(&addr).unwrap();

    let r = client.ping_bin(1).unwrap();
    assert!(r.ok);
    assert_eq!(r.id, 1);

    let mut eye = vec![0.0f32; 64 * 64];
    for i in 0..64 {
        eye[i * 64 + i] = 1.0;
    }
    let r = client.put_a_inline_bin(2, 64, &eye, None).unwrap();
    assert!(r.ok, "{:?}", r.error);
    let h = r.a_handle.expect("binary put_a reply carries the handle");
    assert_eq!(r.n_exec, Some(64));
    assert!(r.convert_ms.unwrap() >= 0.0);

    // The JSON plane dedups identical content to the same handle — both
    // planes share one store.
    let rj = client.put_a_inline(3, 64, &eye, "auto").unwrap();
    assert!(rj.ok);
    assert_eq!(rj.a_handle, Some(h), "planes share the operand store");

    client.shutdown(99).unwrap();
    server.join().unwrap();
}

/// Non-finite-float validation parity (satellite 1): a crafted raw-f32
/// NaN in a binary payload must earn a typed error frame naming the bad
/// element, correlated to the request id — never reach the pipeline.
#[test]
fn crafted_nan_payload_gets_typed_error_frame_with_request_id() {
    let (_coord, addr, server) = boot(one_worker());
    let mut client = Client::connect(&addr).unwrap();

    // Client-side encoder does not screen (the server is the trust
    // boundary): smuggle a quiet NaN into element 3 of A.
    let n = 8usize;
    let mut a = vec![1.0f32; n * n];
    a[3] = f32::from_bits(0x7FC0_0001);
    let b = vec![1.0f32; n * n];
    let (r, c) = client.spdm_inline_bin(42, n, &a, &b, None, false, true).unwrap();
    assert!(!r.ok, "NaN payload must be rejected");
    assert_eq!(r.id, 42, "error frame must carry the request id");
    assert!(c.is_none());
    let err = r.error.unwrap();
    assert!(err.contains("non-finite"), "{err}");
    assert!(err.contains("index 3") && err.contains("in a"), "error names the bad element: {err}");

    // Infinity in B is rejected the same way.
    let a = vec![1.0f32; n * n];
    let mut b = vec![1.0f32; n * n];
    b[7] = f32::INFINITY;
    let (r, _) = client.spdm_inline_bin(43, n, &a, &b, None, false, false).unwrap();
    assert!(!r.ok);
    assert_eq!(r.id, 43);
    let err = r.error.unwrap();
    assert!(err.contains("index 7") && err.contains("in b"), "{err}");

    // The connection survives a payload-level rejection: the next valid
    // request on the same socket still works.
    let r = client.ping_bin(44).unwrap();
    assert!(r.ok);

    client.shutdown(99).unwrap();
    server.join().unwrap();
}

/// Checked-dims validation (PR 8 satellite): a crafted frame whose
/// declared n×n dims disagree with — or arithmetically overflow — the
/// operand bytes it carries earns a typed error frame with the request id
/// *before any buffer is sized*. A 20-byte frame claiming a 60000×60000 A
/// must never turn into a multi-GB reservation, and an n = 2³¹ wrap bait
/// (old unchecked `2·n²·4` ≡ 0 mod 2⁶⁴ matches an empty operand region)
/// must not slip through the length equality.
#[test]
fn crafted_dim_mismatch_and_overflow_frames_get_typed_errors() {
    let (_coord, addr, server) = boot(one_worker());
    let mut stream = TcpStream::connect(&addr).unwrap();

    // Hand-build a raw spdm_inline frame: header + the 14 fixed payload
    // bytes (id u64 | n u32 | flags u8 | algo u8), zero operand bytes.
    let send_tiny_inline = |stream: &mut TcpStream, id: u64, n: u32| {
        let mut payload = Vec::new();
        payload.extend_from_slice(&id.to_le_bytes());
        payload.extend_from_slice(&n.to_le_bytes());
        payload.extend_from_slice(&[0, 0]); // flags, algo auto
        let mut msg = vec![frame::MAGIC, frame::VERSION, frame::FT_SPDM_INLINE];
        msg.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        msg.extend_from_slice(&payload);
        stream.write_all(&msg).unwrap();
        stream.flush().unwrap();
    };
    let read_reply = |stream: &mut TcpStream| {
        let mut hdr = [0u8; frame::HEADER_LEN];
        stream.read_exact(&mut hdr).unwrap();
        let h = frame::parse_header(&hdr).unwrap();
        let mut payload = vec![0u8; h.len];
        stream.read_exact(&mut payload).unwrap();
        frame::decode_response(h.ftype, &payload).unwrap().0
    };

    // Over the 256 MiB frame cap: typed error naming the declared dims.
    send_tiny_inline(&mut stream, 51, 60000);
    let resp = read_reply(&mut stream);
    assert!(!resp.ok);
    assert_eq!(resp.id, 51, "error frame carries the request id");
    let err = resp.error.unwrap();
    assert!(err.contains("60000x60000") && err.contains("overflow"), "{err}");

    // u64 wrap bait on the same (still-open) connection.
    send_tiny_inline(&mut stream, 52, 0x8000_0000);
    let resp = read_reply(&mut stream);
    assert!(!resp.ok);
    assert_eq!(resp.id, 52);
    assert!(resp.error.unwrap().contains("overflow"));

    // Plain mismatch: dims say 8×8 per operand, frame carries 4 floats.
    let short = [1.0f32; 4];
    let bytes = frame::encode_spdm_handle_b(53, 1, 8, &short, None, false, false);
    stream.write_all(&bytes).unwrap();
    stream.flush().unwrap();
    let resp = read_reply(&mut stream);
    assert!(!resp.ok);
    assert_eq!(resp.id, 53);
    let err = resp.error.unwrap();
    assert!(err.contains("expected 1·n²·4"), "typed mismatch error: {err}");

    // Dim rejections are payload-level: the same socket still serves.
    stream.write_all(&frame::encode_ping(54)).unwrap();
    stream.flush().unwrap();
    let resp = read_reply(&mut stream);
    assert!(resp.ok, "connection survives dim rejections");
    assert_eq!(resp.id, 54);
    drop(stream);

    let mut client = Client::connect(&addr).unwrap();
    client.shutdown(99).unwrap();
    server.join().unwrap();
}

/// A bad frame header (wrong version under the real magic) is
/// unresyncable: the server replies with a typed error frame and closes
/// the connection.
#[test]
fn bad_frame_version_gets_error_frame_then_close() {
    let (_coord, addr, server) = boot(one_worker());
    let mut stream = TcpStream::connect(&addr).unwrap();
    // Magic right, version wrong, plausible length: the sniffer routes to
    // the binary plane, the header parse rejects.
    let junk = [frame::MAGIC, 0x7F, 0x01, 4, 0, 0, 0];
    stream.write_all(&junk).unwrap();
    stream.flush().unwrap();
    let mut hdr = [0u8; frame::HEADER_LEN];
    stream.read_exact(&mut hdr).unwrap();
    let h = frame::parse_header(&hdr).unwrap();
    let mut payload = vec![0u8; h.len];
    stream.read_exact(&mut payload).unwrap();
    let (resp, _) = frame::decode_response(h.ftype, &payload).unwrap();
    assert!(!resp.ok);
    assert!(resp.error.unwrap().contains("version"));
    // …then EOF: the stream was closed server-side.
    let mut rest = Vec::new();
    stream.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "server must close after an unresyncable header");

    // Non-magic junk falls through to the JSON plane and earns a JSON
    // parse-error line instead (the debug plane stays line-oriented).
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.write_all(b"hello wire\n").unwrap();
    stream.flush().unwrap();
    let mut reply = String::new();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    std::io::BufRead::read_line(&mut reader, &mut reply).unwrap();
    assert!(reply.contains("\"ok\":false"), "junk line gets a JSON error reply: {reply}");

    // Shut the server down over a fresh, well-formed connection.
    let mut client = Client::connect(&addr).unwrap();
    client.shutdown(99).unwrap();
    server.join().unwrap();
}

/// Admission-window differential (tentpole): the same workload through a
/// window=0 coordinator and a window-on coordinator must produce bitwise
/// identical checksums — the window changes batching choices (every
/// window-on batch carries an outcome: hits + timeouts = total batches),
/// never results. window=0 keeps the counters at zero.
#[test]
fn admission_window_changes_batching_never_results() {
    let base = one_worker();
    let windowed = CoordinatorConfig { admission_window_us: 20_000, ..base };

    // A shared-A workload (identity A, varying B) plus a lone non-affine
    // request, run through both coordinators.
    let n = 64usize;
    let mut eye = vec![0.0f32; n * n];
    for i in 0..n {
        eye[i * n + i] = 1.0;
    }
    let run = |cfg: CoordinatorConfig| -> (Vec<u64>, gcoospdm::coordinator::MetricsSnapshot) {
        let coord = Coordinator::new(Arc::new(runnable_registry()), cfg);
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            let mut rng = Rng::new(500 + i);
            let b = Mat::randn(n, n, &mut rng);
            let a = Mat::from_vec(n, n, eye.clone());
            rxs.push(coord.submit(SpdmRequest::new(i, a, b)).unwrap());
        }
        let mut sums = Vec::new();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            let c = resp.c.expect("response carries C");
            let sum: f64 = c.data.iter().map(|x| *x as f64).sum();
            sums.push(sum.to_bits());
        }
        let snap = coord.snapshot();
        coord.shutdown();
        (sums, snap)
    };

    let (sums0, snap0) = run(base);
    let (sums_w, snap_w) = run(windowed);
    assert_eq!(sums0, sums_w, "window must never change results");

    assert_eq!(snap0.window_hits, 0, "window off ⇒ no outcome counters");
    assert_eq!(snap0.window_timeouts, 0);

    let batches_w: u64 = snap_w.batch_hist.iter().sum();
    assert_eq!(
        snap_w.window_hits + snap_w.window_timeouts,
        batches_w,
        "every window-on batch carries exactly one outcome"
    );
    assert_eq!(snap_w.batched_jobs(), 6, "all jobs accounted in the width histogram");
}

/// JSON-plane operand pre-allocation cap (ISSUE 9 satellite): the binary
/// plane's 256 MiB cap applies to huge inline `a`/`b` declarations on the
/// JSON plane too. The rejection fires on the *declared* dims — the tiny
/// inline arrays these requests actually carry prove no n²-sized buffer
/// was needed to say no — the error is typed, and the connection
/// survives to serve the next request.
#[test]
fn json_inline_operand_cap_rejects_huge_declarations_connection_survives() {
    let (_coord, addr, server) = boot(one_worker());
    let mut client = Client::connect(&addr).unwrap();

    // spdm inline: 2·n²·4 bytes at n=16384 is 2 GiB, far over the cap.
    let r = client.spdm_inline(61, 16384, &[1.0], &[1.0], false).unwrap();
    assert!(!r.ok, "over-cap spdm must be rejected");
    assert_eq!(r.id, 61, "error reply carries the request id");
    let err = r.error.unwrap();
    assert!(
        err.contains("exceed") && err.contains("16384x16384"),
        "typed cap error names the declared dims: {err}"
    );

    // put_a inline: 1·n²·4 bytes at n=16384 is 1 GiB.
    let r = client.put_a_inline(62, 16384, &[1.0], "auto").unwrap();
    assert!(!r.ok, "over-cap put_a must be rejected");
    assert_eq!(r.id, 62);
    let err = r.error.unwrap();
    assert!(err.contains("exceed") && err.contains("put_a"), "{err}");

    // The cap is a payload-level rejection: the same socket still serves,
    // and an under-cap request of the usual size goes through.
    assert!(client.ping(63).unwrap().ok, "connection survives cap rejections");
    let n = 64usize;
    let mut rng = Rng::new(77);
    let a = gen::generate(gen::Pattern::Uniform, n, 0.9, &mut rng);
    let b = Mat::randn(n, n, &mut rng);
    let r = client.spdm_inline(64, n, &a.data, &b.data, false).unwrap();
    assert!(r.ok, "{:?}", r.error);

    client.shutdown(99).unwrap();
    server.join().unwrap();
}
