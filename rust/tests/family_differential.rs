//! Family differential (ISSUE 10 tentpole acceptance): the execution
//! families — GCOO, CSR/ELL, dense, CMRS, row-split — are **bitwise
//! interchangeable**. Every family accumulates each output element over
//! ascending k in f32 from 0.0, so which family runs is pure routing
//! provenance, never visible in the numbers.
//!
//! * The core sweep: all 9 corpus patterns (adversarial families
//!   included) × widths {1, 2, batch_max} × all five hintable families,
//!   fused batch execution, with matching (n=64) and padded (n=60)
//!   request sizes — every response C bitwise identical across families.
//! * The wire sweep: per pattern, a CMRS-registered handle and a
//!   row-split-registered handle on a slice-over-subscribed spilling
//!   coordinator answer on **both wire planes** with checksums bitwise
//!   equal to an untenanted auto-routed inline baseline — across GSPL
//!   demote → promote round trips of both new operand encodings, with
//!   zero reconversions.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use gcoospdm::coordinator::{
    process_batch_ws, Algo, BatchJob, Coordinator, CoordinatorConfig, SpdmRequest, SpdmResponse,
    TenantSpec, Workspace,
};
use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::{Engine, Registry};
use gcoospdm::serve::{Client, Server, ServerConfig};

/// Stub registry at n=64 carrying every family (the engine only needs
/// artifact files to exist; distinct target dir so parallel test binaries
/// never race on the files).
fn runnable_registry() -> Registry {
    let dir = PathBuf::from("target/family_differential_artifacts");
    std::fs::create_dir_all(&dir).expect("create stub artifact dir");
    std::fs::write(dir.join("stub.hlo.txt"), b"stub").expect("write stub artifact");
    let manifest = r#"{"artifacts": [
        {"name": "gcoo_n64_cap512", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "csr_n64_rowcap64", "algo": "csr", "n": 64,
         "params": {"rp": 8, "rowcap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "dense_xla_n64", "algo": "dense_xla", "n": 64,
         "params": {}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "cmrs_n64_cap512", "algo": "cmrs", "n": 64,
         "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "rowsplit_n64_cap64", "algo": "rowsplit", "n": 64,
         "params": {"cap": 64}, "inputs": [], "file": "stub.hlo.txt"}
    ]}"#;
    Registry::from_manifest_json(manifest, dir).expect("stub manifest parses")
}

const N: usize = 64;
const FAMILIES: [Algo; 5] = [Algo::Gcoo, Algo::Csr, Algo::DenseXla, Algo::Cmrs, Algo::RowSplit];

/// The core sweep: 9 patterns × widths {1, 2, batch_max} × all five
/// families, fused execution, bitwise identity against the GCOO
/// reference in every cell.
#[test]
fn all_families_bitwise_identical_across_corpus_and_widths() {
    let reg = runnable_registry();
    let engine = Engine::new().unwrap();
    let cfg = CoordinatorConfig::default();
    let widths = [1usize, 2, cfg.batch_max];
    let mut rng = Rng::new(0xFA41);
    let mut cells = 0usize;
    for (pi, pattern) in gen::Pattern::ALL.iter().enumerate() {
        // Alternate matching and padded-up execution sizes so every
        // family's conversion crosses the pad border too.
        let n = if pi % 2 == 0 { 64 } else { 60 };
        let a = gen::generate(*pattern, n, 0.9, &mut rng);
        for &w in &widths {
            let bs: Vec<Mat> = (0..w).map(|_| Mat::randn(n, n, &mut rng)).collect();
            let mut reference: Option<Vec<SpdmResponse>> = None;
            for family in FAMILIES {
                let reqs: Vec<SpdmRequest> = bs
                    .iter()
                    .enumerate()
                    .map(|(i, b)| {
                        let mut r = SpdmRequest::new(i as u64, a.clone(), b.clone());
                        r.algo_hint = Some(family);
                        // One oracle check per family per cell pins each
                        // family to the true product, not just to GCOO.
                        r.verify = i == 0;
                        r
                    })
                    .collect();
                let jobs: Vec<BatchJob<'_>> =
                    reqs.iter().map(|r| BatchJob::inline(r, Instant::now())).collect();
                let mut ws = Workspace::new();
                let resps = process_batch_ws(&engine, &mut ws, &reg, &cfg, &jobs);
                let ctx = format!("{}/{}/w{w}/n{n}", pattern.name(), family.as_str());
                for (i, r) in resps.iter().enumerate() {
                    assert!(r.ok(), "{ctx}[{i}]: {:?}", r.error);
                    assert_eq!(r.algo, family, "{ctx}[{i}]: the hint must win");
                    if i == 0 {
                        assert_eq!(r.verified, Some(true), "{ctx}: oracle");
                    }
                }
                match &reference {
                    None => reference = Some(resps),
                    Some(base) => {
                        for (i, (b_resp, f_resp)) in base.iter().zip(&resps).enumerate() {
                            assert!(
                                b_resp.c == f_resp.c,
                                "{ctx}[{i}]: C is not bitwise identical to {}",
                                FAMILIES[0].as_str()
                            );
                        }
                    }
                }
            }
            cells += 1;
        }
    }
    assert_eq!(cells, 9 * 3, "full corpus × width matrix covered");
}

fn boot(cfg: CoordinatorConfig) -> (Arc<Coordinator>, String, std::thread::JoinHandle<()>) {
    let coord = Arc::new(Coordinator::new(Arc::new(runnable_registry()), cfg));
    let server = Server::bind(&ServerConfig::ephemeral(), Arc::clone(&coord)).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        let _ = server.run();
    });
    (coord, addr, handle)
}

fn tmp_dir(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gcoospdm_familydiff_{}_{name}", std::process::id()))
}

/// The wire sweep: per pattern, CMRS and row-split handles on a
/// slice-over-subscribed spilling coordinator serve bitwise-identical
/// checksums on both planes across GSPL demote → promote round trips of
/// both new operand encodings, with zero reconversions.
#[test]
fn cmrs_and_rowsplit_handles_spill_round_trip_bitwise_on_both_planes() {
    let registry = Arc::new(runnable_registry());
    for (pi, pat) in gen::Pattern::ALL.iter().enumerate() {
        let mut rng = Rng::new(0xF001 + pi as u64);
        let a = gen::generate(*pat, N, 0.9, &mut rng);
        let b = Mat::randn(N, N, &mut rng);
        let mut rng2 = Rng::new(0xF101 + pi as u64);
        let filler = gen::generate(gen::Pattern::Uniform, N, 0.9, &mut rng2);
        let fb = Mat::randn(N, N, &mut rng2);

        // Untenanted auto-routed inline baselines on the JSON plane.
        let (_c0, addr0, s0) =
            boot(CoordinatorConfig { workers: 1, ..Default::default() });
        let mut base = Client::connect(&addr0).unwrap();
        let r = base.spdm_inline(1, N, &a.data, &b.data, false).unwrap();
        assert!(r.ok, "{}: baseline a: {:?}", pat.name(), r.error);
        let base_a = r.checksum.unwrap().to_bits();
        let r = base.spdm_inline(2, N, &filler.data, &fb.data, false).unwrap();
        assert!(r.ok, "{}: baseline filler: {:?}", pat.name(), r.error);
        let base_f = r.checksum.unwrap().to_bits();
        base.shutdown(9_998).unwrap();
        s0.join().unwrap();

        // A slice that fits either family's operand alone but never both.
        let meter = Coordinator::new(
            Arc::clone(&registry),
            CoordinatorConfig { workers: 1, ..Default::default() },
        );
        let ea = meter.put_a(a.clone(), Some(Algo::Cmrs)).unwrap();
        let ef = meter.put_a(filler.clone(), Some(Algo::RowSplit)).unwrap();
        let slice = (ea.bytes.max(ef.bytes) + ea.bytes + ef.bytes) / 2;
        meter.shutdown();

        let dir = tmp_dir(pat.name());
        let cfg = CoordinatorConfig {
            workers: 1,
            tenants: vec![TenantSpec {
                name: "solo".into(),
                weight: 1,
                rate_per_s: 0.0,
                burst: 0.0,
                store_slice_bytes: slice,
            }],
            spill_dir: Some(dir.clone()),
            ..Default::default()
        };
        let (coord, addr, server) = boot(cfg);
        let mut client = Client::connect(&addr).unwrap();
        client.set_tenant(Some("solo"));

        let r = client.put_a_inline(10, N, &a.data, "cmrs").unwrap();
        assert!(r.ok, "{}: cmrs put_a: {:?}", pat.name(), r.error);
        let ha = r.a_handle.unwrap();
        let r = client.spdm_handle(11, ha, &b.data, false).unwrap();
        assert!(r.ok, "{}: cmrs pre-spill: {:?}", pat.name(), r.error);
        assert_eq!(r.checksum.unwrap().to_bits(), base_a, "{}: cmrs JSON plane", pat.name());

        // Registering the row-split filler overflows the slice and
        // demotes the CMRS operand into the GSPL tier.
        let r = client.put_a_inline(12, N, &filler.data, "rowsplit").unwrap();
        assert!(r.ok, "{}: rowsplit put_a: {:?}", pat.name(), r.error);
        let hf = r.a_handle.unwrap();
        assert!(
            coord.store().stats().spill_writes >= 1,
            "{}: filler registration must demote the CMRS operand",
            pat.name()
        );

        // Binary plane revisit promotes the CMRS operand from disk.
        let (r, _) = client.spdm_handle_bin(13, ha, N, &b.data, None, false, false).unwrap();
        assert!(r.ok, "{}: cmrs promote: {:?}", pat.name(), r.error);
        assert_eq!(
            r.checksum.unwrap().to_bits(),
            base_a,
            "{}: CMRS binary plane after GSPL round trip",
            pat.name()
        );
        // JSON plane revisit promotes the row-split operand back in turn.
        let r = client.spdm_handle(14, hf, &fb.data, false).unwrap();
        assert!(r.ok, "{}: rowsplit promote: {:?}", pat.name(), r.error);
        assert_eq!(
            r.checksum.unwrap().to_bits(),
            base_f,
            "{}: row-split JSON plane after GSPL round trip",
            pat.name()
        );

        let snap = coord.snapshot();
        assert!(
            snap.spill_promotes >= 2,
            "{}: both encodings round-tripped through disk ({} promotes)",
            pat.name(),
            snap.spill_promotes
        );
        assert_eq!(
            snap.conversions_total, 2,
            "{}: the two registrations are the only conversions — promotes pay none",
            pat.name()
        );

        client.shutdown(9_999).unwrap();
        server.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
