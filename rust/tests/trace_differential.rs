//! Trace-vs-walker differential suite — the lockdown for the trace-driven
//! inversion (DESIGN.md §Tracing).
//!
//! The traced adapters (`gcoo_walk`/`csr_walk`/`gemm_walk`, now event
//! streams replayed through the memory model) are pinned **exactly** to the
//! legacy hand-derived walkers (`hand_*`, kept verbatim as the differential
//! baseline) across the six corpus pattern families, at a power-of-two size
//! (n=64) and a ragged size (n=60) that exercises every partial-warp /
//! partial-tile edge. Recorded traces must replay to the same counters as
//! streaming replay, deterministically run-to-run, on every Table II
//! device; and the traces the *instrumented engine kernels* emit must equal
//! the traces the walkers record for the same problem.

use std::path::PathBuf;

use gcoospdm::gen::{self, Pattern};
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::{Engine, Registry};
use gcoospdm::simgpu::{
    csr_walk, gcoo_walk, gemm_walk, hand_csr_walk, hand_gcoo_walk, hand_gemm_walk, record_csr,
    record_gcoo, record_gemm, GcooStructure, TraceRecorder, WalkConfig, ALL_DEVICES, TITANX,
};
use gcoospdm::sparse::{Csr, Ell, Gcoo};

/// n=64: exact block/warp multiples. n=60: ragged rows, partial warps,
/// n % j_samples != 0 (the column-sampling scale is a non-trivial float).
const SIZES: [usize; 2] = [64, 60];
const SPARSITY: f64 = 0.9;

/// One matrix per (pattern family, size), deterministic seeds.
fn corpus() -> Vec<(Pattern, usize, Mat)> {
    let mut out = Vec::new();
    for (pi, &pat) in Pattern::ALL.iter().enumerate() {
        for &n in &SIZES {
            let mut rng = Rng::new(0x7D1F ^ ((pi as u64) << 8) ^ n as u64);
            out.push((pat, n, gen::generate(pat, n, SPARSITY, &mut rng)));
        }
    }
    out
}

/// Satellite 1 core: traced counters agree with the legacy hand walkers
/// *exactly* (the walker is itself exact over the sampled window, so the
/// traced stream must reproduce every transaction, not just totals).
#[test]
fn traced_adapters_match_hand_walkers_across_corpus() {
    let cfg = WalkConfig::default();
    for (pat, n, a) in corpus() {
        let st = GcooStructure::new(&Gcoo::from_dense(&a, 8));
        for reuse in [true, false] {
            assert_eq!(
                gcoo_walk(&st, &TITANX, &cfg, reuse),
                hand_gcoo_walk(&st, &TITANX, &cfg, reuse),
                "gcoo {} n={n} reuse={reuse}",
                pat.name()
            );
        }
        assert_eq!(
            csr_walk(&st, &TITANX, &cfg),
            hand_csr_walk(&st, &TITANX, &cfg),
            "csr {} n={n}",
            pat.name()
        );
        assert_eq!(
            gemm_walk(n, &TITANX, &cfg),
            hand_gemm_walk(n, &TITANX, &cfg),
            "gemm n={n}"
        );
    }
}

/// Recording a trace and replaying it must equal streaming replay — on
/// every Table II device (the trace is device-independent; classification
/// happens at replay).
#[test]
fn recorded_replay_matches_streaming_on_all_devices() {
    let cfg = WalkConfig::default();
    for &n in &SIZES {
        let mut rng = Rng::new(0xA11D ^ n as u64);
        let a = gen::generate(Pattern::Uniform, n, SPARSITY, &mut rng);
        let st = GcooStructure::new(&Gcoo::from_dense(&a, 8));
        let tg = record_gcoo(&st, &cfg, true);
        let tc = record_csr(&st, &cfg);
        let tm = record_gemm(n, &cfg);
        for dev in ALL_DEVICES {
            assert_eq!(tg.replay(dev), gcoo_walk(&st, dev, &cfg, true), "gcoo {} n={n}", dev.name);
            assert_eq!(tc.replay(dev), csr_walk(&st, dev, &cfg), "csr {} n={n}", dev.name);
            assert_eq!(tm.replay(dev), gemm_walk(n, dev, &cfg), "gemm {} n={n}", dev.name);
        }
    }
}

/// Traced replay is deterministic run-to-run: identical trace objects,
/// identical replayed counters, no hidden state.
#[test]
fn traced_replay_is_deterministic_run_to_run() {
    let cfg = WalkConfig::default();
    for (pat, n, a) in corpus() {
        let st = GcooStructure::new(&Gcoo::from_dense(&a, 8));
        let t1 = record_gcoo(&st, &cfg, true);
        let t2 = record_gcoo(&st, &cfg, true);
        assert_eq!(t1, t2, "gcoo trace {} n={n} not reproducible", pat.name());
        assert_eq!(t1.replay(&TITANX), t1.replay(&TITANX), "gcoo replay {} n={n}", pat.name());
        let c1 = record_csr(&st, &cfg);
        let c2 = record_csr(&st, &cfg);
        assert_eq!(c1, c2, "csr trace {} n={n} not reproducible", pat.name());
        assert_eq!(c1.replay(&TITANX), c1.replay(&TITANX), "csr replay {} n={n}", pat.name());
    }
    let m1 = record_gemm(60, &cfg);
    assert_eq!(m1, record_gemm(60, &cfg));
    assert_eq!(m1.replay(&TITANX), m1.replay(&TITANX));
}

/// Registry of runnable stub artifacts at n=64 (the engine only needs the
/// files to exist — same pattern as tests/zero_copy.rs).
fn runnable_registry() -> Registry {
    let dir = PathBuf::from("target/trace_differential_artifacts");
    std::fs::create_dir_all(&dir).expect("create stub artifact dir");
    std::fs::write(dir.join("stub.hlo.txt"), b"stub").expect("write stub artifact");
    let manifest = r#"{"artifacts": [
        {"name": "gcoo_n64_cap64", "algo": "gcoo", "n": 64,
         "params": {"p": 8, "cap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "gcoo_noreuse_n64_cap64", "algo": "gcoo_noreuse", "n": 64,
         "params": {"p": 8, "cap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "csr_n64_rowcap64", "algo": "csr", "n": 64,
         "params": {"rp": 8, "rowcap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "dense_xla_n64", "algo": "dense_xla", "n": 64,
         "params": {}, "inputs": [], "file": "stub.hlo.txt"}
    ]}"#;
    Registry::from_manifest_json(manifest, dir).expect("stub manifest parses")
}

/// The tentpole's closing identity: the trace the *instrumented reference
/// kernels* emit during real execution equals the trace the walker records
/// for the same problem — at the exact size and with a ragged matrix
/// zero-padded to the artifact size (the serving path's shape).
#[test]
fn engine_recorded_traces_match_walker_traces() {
    let reg = runnable_registry();
    let engine = Engine::new().unwrap();
    let cfg = WalkConfig::default();
    for &n in &SIZES {
        let mut rng = Rng::new(0xE7 ^ n as u64);
        let a_raw = gen::generate(Pattern::Uniform, n, 0.95, &mut rng);
        let b_raw = Mat::randn(n, n, &mut rng);
        let mut a = Mat::zeros(0, 0);
        a.pad_from(&a_raw, 64);
        let mut b = Mat::zeros(0, 0);
        b.pad_from(&b_raw, 64);

        // GCOO, both reuse variants.
        let gcoo = Gcoo::from_dense(&a, 8);
        assert!(gcoo.max_group_nnz() <= 64, "workload must fit the cap=64 artifact");
        let padded = gcoo.pad(64).unwrap();
        let st = GcooStructure::new(&gcoo);
        for reuse in [true, false] {
            let mut rec = TraceRecorder::new();
            let mut c = Mat::zeros(0, 0);
            engine
                .run_gcoo_slabs_into_sink(&reg, padded.as_slabs(), &b, reuse, &mut c, &mut rec)
                .unwrap();
            assert!(c.allclose(&a.matmul(&b), 1e-3, 1e-3), "tracing must not perturb C");
            assert_eq!(
                rec.finish(),
                record_gcoo(&st, &cfg, reuse),
                "engine gcoo trace != walker trace (n={n} reuse={reuse})"
            );
        }

        // CSR (ELL-backed kernel).
        let ell = Ell::from_csr(&Csr::from_dense(&a), 64).unwrap();
        let mut rec = TraceRecorder::new();
        let mut c = Mat::zeros(0, 0);
        engine.run_ell_slabs_into_sink(&reg, ell.as_slabs(), &b, &mut c, &mut rec).unwrap();
        assert!(c.allclose(&a.matmul(&b), 1e-3, 1e-3), "tracing must not perturb C");
        assert_eq!(rec.finish(), record_csr(&st, &cfg), "engine csr trace != walker trace (n={n})");

        // Dense tiled GEMM.
        let mut rec = TraceRecorder::new();
        engine.run_dense_sink(&reg, "dense_xla", &a, &b, &mut rec).unwrap();
        assert_eq!(rec.finish(), record_gemm(64, &cfg), "engine dense trace != walker trace (n={n})");
    }
}
