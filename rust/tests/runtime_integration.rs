//! Integration: the PJRT engine executing real AOT artifacts against the
//! CPU oracle. Requires `make artifacts` (tests no-op with a notice
//! otherwise, so `cargo test` stays runnable on a fresh clone).

use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::{Engine, Registry};
use gcoospdm::sparse::{Csr, Ell, Gcoo};

fn setup() -> Option<(Registry, Engine)> {
    let reg = match Registry::load("artifacts") {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping runtime integration ({e}); run `make artifacts`");
            return None;
        }
    };
    let engine = Engine::new().expect("PJRT CPU client");
    Some((reg, engine))
}

fn spdm_case(n: usize, sparsity: f64, seed: u64) -> (Mat, Mat, Mat) {
    let mut rng = Rng::new(seed);
    let a = gen::uniform(n, sparsity, &mut rng);
    let b = Mat::randn(n, n, &mut rng);
    let oracle = a.matmul(&b);
    (a, b, oracle)
}

#[test]
fn gcoo_artifact_matches_oracle() {
    let Some((reg, engine)) = setup() else { return };
    let (a, b, oracle) = spdm_case(256, 0.99, 1);
    let gcoo = Gcoo::from_dense(&a, 8);
    let padded = gcoo.pad(gcoo.max_group_nnz()).unwrap();
    let out = engine.run_gcoo(&reg, &padded, &b, true).unwrap();
    assert!(
        out.c.allclose(&oracle, 1e-3, 1e-3),
        "max diff {}",
        out.c.max_abs_diff(&oracle)
    );
    assert!(out.kernel_s > 0.0);
    assert!(out.artifact.starts_with("gcoo_n256"));
}

#[test]
fn gcoo_noreuse_matches_reuse() {
    let Some((reg, engine)) = setup() else { return };
    let (a, b, _oracle) = spdm_case(256, 0.98, 2);
    let gcoo = Gcoo::from_dense(&a, 8);
    let padded = gcoo.pad(gcoo.max_group_nnz()).unwrap();
    let with = engine.run_gcoo(&reg, &padded, &b, true).unwrap();
    let without = engine.run_gcoo(&reg, &padded, &b, false).unwrap();
    assert_eq!(with.c, without.c, "reuse flag must not change numerics");
}

#[test]
fn csr_artifact_matches_oracle() {
    let Some((reg, engine)) = setup() else { return };
    let (a, b, oracle) = spdm_case(256, 0.99, 3);
    let csr = Csr::from_dense(&a);
    let ell = Ell::from_csr(&csr, csr.max_row_nnz()).unwrap();
    let out = engine.run_csr(&reg, &ell, &b).unwrap();
    assert!(out.c.allclose(&oracle, 1e-3, 1e-3));
}

#[test]
fn dense_artifacts_match_oracle() {
    let Some((reg, engine)) = setup() else { return };
    let mut rng = Rng::new(4);
    let a = Mat::randn(256, 256, &mut rng);
    let b = Mat::randn(256, 256, &mut rng);
    let oracle = a.matmul(&b);
    for algo in ["dense_xla", "dense_pallas"] {
        let out = engine.run_dense(&reg, algo, &a, &b).unwrap();
        assert!(
            out.c.allclose(&oracle, 1e-2, 1e-2),
            "{algo}: max diff {}",
            out.c.max_abs_diff(&oracle)
        );
    }
}

#[test]
fn capacity_routing_picks_smallest_fitting() {
    let Some((reg, _engine)) = setup() else { return };
    // caps at n=256 are {64, 256, 1024}
    assert_eq!(reg.select("gcoo", 256, 10).unwrap().param("cap"), Some(64));
    assert_eq!(reg.select("gcoo", 256, 100).unwrap().param("cap"), Some(256));
    assert_eq!(reg.select("gcoo", 256, 1000).unwrap().param("cap"), Some(1024));
    assert!(reg.select("gcoo", 256, 5000).is_err());
}

#[test]
fn engine_compile_cache_reuses_executables() {
    let Some((reg, engine)) = setup() else { return };
    let (a, b, _) = spdm_case(256, 0.99, 5);
    let gcoo = Gcoo::from_dense(&a, 8);
    let padded = gcoo.pad(gcoo.max_group_nnz()).unwrap();
    engine.run_gcoo(&reg, &padded, &b, true).unwrap();
    let after_first = engine.compiled_count();
    engine.run_gcoo(&reg, &padded, &b, true).unwrap();
    assert_eq!(engine.compiled_count(), after_first, "second run must hit the cache");
    assert_eq!(engine.compile_log().len(), after_first);
}

#[test]
fn engine_repads_to_artifact_capacity() {
    let Some((reg, engine)) = setup() else { return };
    // Provide padding at a non-exported cap; engine must re-pad to cap=64.
    let (a, b, oracle) = spdm_case(256, 0.995, 6);
    let gcoo = Gcoo::from_dense(&a, 8);
    let padded = gcoo.pad(37).unwrap_or_else(|_| gcoo.pad(gcoo.max_group_nnz()).unwrap());
    let out = engine.run_gcoo(&reg, &padded, &b, true).unwrap();
    assert!(out.c.allclose(&oracle, 1e-3, 1e-3));
}

#[test]
fn shape_mismatch_rejected() {
    let Some((reg, engine)) = setup() else { return };
    let mut rng = Rng::new(7);
    let a = Mat::randn(256, 256, &mut rng);
    let b_bad = Mat::randn(128, 128, &mut rng);
    // B at an exported size but different from A's: select() finds the
    // n=128-fitting artifact only if one exists; shapes must be caught.
    let err = engine.run_dense(&reg, "dense_xla", &a, &b_bad);
    assert!(err.is_err());
}

#[test]
fn spmv_extension_matches_oracle() {
    let Some((reg, engine)) = setup() else { return };
    let mut rng = Rng::new(21);
    let a = gen::uniform(256, 0.99, &mut rng);
    let x: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
    let gcoo = Gcoo::from_dense(&a, 8);
    let padded = gcoo.pad(gcoo.max_group_nnz()).unwrap();
    let (y, kernel_s, artifact) = engine.run_gcoo_spmv(&reg, &padded, &x).unwrap();
    assert!(artifact.starts_with("gcoo_spmv_n256"));
    assert!(kernel_s > 0.0);
    let oracle = a.matmul(&Mat::from_vec(256, 1, x));
    for (i, (got, want)) in y.iter().zip(&oracle.data).enumerate() {
        assert!((got - want).abs() < 1e-3, "y[{i}]: {got} vs {want}");
    }
}

#[test]
fn spmv_rejects_oversized_band() {
    let Some((reg, engine)) = setup() else { return };
    let mut rng = Rng::new(22);
    let a = gen::uniform(256, 0.2, &mut rng); // dense: bands exceed any cap
    let gcoo = Gcoo::from_dense(&a, 8);
    let padded = gcoo.pad(gcoo.max_group_nnz()).unwrap();
    let x = vec![1.0f32; 256];
    assert!(engine.run_gcoo_spmv(&reg, &padded, &x).is_err());
}

#[test]
fn structured_patterns_execute_correctly() {
    let Some((reg, engine)) = setup() else { return };
    for (i, pattern) in [gen::Pattern::Diagonal, gen::Pattern::DenseColumns, gen::Pattern::Banded]
        .into_iter()
        .enumerate()
    {
        let mut rng = Rng::new(100 + i as u64);
        let a = gen::generate(pattern, 256, 0.99, &mut rng);
        let b = Mat::randn(256, 256, &mut rng);
        let oracle = a.matmul(&b);
        let gcoo = Gcoo::from_dense(&a, 8);
        let padded = gcoo.pad(gcoo.max_group_nnz().max(1)).unwrap();
        let out = engine.run_gcoo(&reg, &padded, &b, true).unwrap();
        assert!(
            out.c.allclose(&oracle, 1e-3, 1e-3),
            "{}: max diff {}",
            pattern.name(),
            out.c.max_abs_diff(&oracle)
        );
    }
}
