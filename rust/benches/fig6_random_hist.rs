//! Bench: regenerate paper Fig 6 (random-matrix speedup histogram, 3 GPUs).
fn main() {
    let count = std::env::var("FIG6_COUNT").ok().and_then(|v| v.parse().ok()).unwrap_or(300);
    let max_n = std::env::var("FIG6_MAX_N").ok().and_then(|v| v.parse().ok()).unwrap_or(2048);
    gcoospdm::figures::fig6_random_hist(count, max_n).print();
}
