//! Bench: regenerate paper Fig 5 / Table III (14 selected matrices, P100).
fn main() {
    let max_n = std::env::var("FIG5_MAX_N").ok().and_then(|v| v.parse().ok()).unwrap_or(1536);
    gcoospdm::figures::fig5_selected(max_n).print();
}
