//! Bench: regenerate paper Figs 7-9 (time vs sparsity, n ∈ {4000, 14000},
//! GTX980 / TitanX / P100, including the cuBLAS constant line).
fn main() {
    gcoospdm::figures::fig7_9_time_vs_sparsity().print();
}
