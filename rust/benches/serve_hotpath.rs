//! Serving hot-path benchmark: requests/sec through the coordinator at
//! fixed seeds, plus the allocations-avoided counters, an A/B of the
//! zero-copy arena pipeline against a faithful replica of the pre-arena
//! copy-heavy path (pad A → convert → pad again → clone slabs), a
//! batched-vs-sequential A/B of fused multi-B execution (one A conversion
//! + one wide kernel per batch vs one conversion per request), a
//! handle-vs-inline A/B of the operand store (register A once, multiply
//! by reference vs re-ship + re-convert per request — EO amortization),
//! a binary-v3-vs-JSON-v2 wire A/B through a live server (bitwise-checked
//! checksums, req/s + bytes-on-wire per request), an open-loop
//! arrival-schedule phase measuring achieved fused-batch width and
//! latency percentiles with the admission window on vs off, and a
//! cluster-vs-single A/B: the same handle workload through one plain
//! server vs a 3-node sharded cluster behind the consistent-hash router
//! (bitwise-checked checksums, req/s both sides = router overhead),
//! a hot-tenant-vs-fair A/B (a flooding tenant ahead of a light tenant:
//! FIFO vs weighted DRR lanes, bitwise-checked checksums, light tenant's
//! time-to-drain both sides), and a spill-promote-vs-reconvert A/B (a
//! demoted handle served by one sequential slab read vs re-shipping A
//! inline and reconverting per request — bitwise-checked checksums and
//! a conversion counter pinned across the promote cycles), and a kernel
//! family A/B (GCOO vs CMRS vs row-split hinted over the same extreme-skew
//! fixed-seed workload, bitwise-checked, req/s per family).
//!
//! The engine only needs artifact files to *exist*, so the bench fabricates
//! a runnable registry under `target/` — no `make artifacts` required.
//!
//! Besides the printed lines, every run emits a machine-readable summary
//! (`BENCH_10.json` at the repo root, or `$BENCH_JSON`): req/s per phase,
//! latency percentiles, wire bytes per request, and the
//! copy/conversion/flip/window counters. The document is stamped
//! `"provenance": "measured"` — the checked-in placeholder lacks that
//! stamp, which is how `ci.sh --quick` tells the two apart.
//!
//!   cargo bench --bench serve_hotpath            # full run
//!   cargo bench --bench serve_hotpath -- --quick # CI quick mode (ci.sh)

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use gcoospdm::convert;
use gcoospdm::json::{self, Value};
use gcoospdm::coordinator::{
    process_batch_ws, process_one_ws, Algo, BatchJob, Coordinator, CoordinatorConfig, Selector,
    SpdmRequest, TenantSpec, TunerConfig, Workspace,
};
use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::{Engine, Registry};
use gcoospdm::serve::{Client, Cluster, ClusterConfig, Server, ServerConfig};
use gcoospdm::sparse::GcooPadded;

fn registry() -> Registry {
    let dir = PathBuf::from("target/serve_hotpath_artifacts");
    std::fs::create_dir_all(&dir).expect("create stub artifact dir");
    std::fs::write(dir.join("stub.hlo.txt"), b"stub").expect("write stub artifact");
    let manifest = r#"{"artifacts": [
        {"name": "gcoo_n256_cap64", "algo": "gcoo", "n": 256,
         "params": {"p": 8, "cap": 64}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "gcoo_n256_cap256", "algo": "gcoo", "n": 256,
         "params": {"p": 8, "cap": 256}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "gcoo_n256_cap1024", "algo": "gcoo", "n": 256,
         "params": {"p": 8, "cap": 1024}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "csr_n256_rowcap128", "algo": "csr", "n": 256,
         "params": {"rp": 8, "rowcap": 128}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "dense_xla_n256", "algo": "dense_xla", "n": 256,
         "params": {}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "cmrs_n256_cap1024", "algo": "cmrs", "n": 256,
         "params": {"p": 8, "cap": 1024}, "inputs": [], "file": "stub.hlo.txt"},
        {"name": "rowsplit_n256_cap128", "algo": "rowsplit", "n": 256,
         "params": {"cap": 128}, "inputs": [], "file": "stub.hlo.txt"}
    ]}"#;
    Registry::from_manifest_json(manifest, dir).expect("stub manifest parses")
}

/// Fixed-seed workload: alternating exact-size (256) and padded (200)
/// sparse requests, with every 5th request dense-routed.
fn workload(count: usize) -> Vec<SpdmRequest> {
    (0..count)
        .map(|i| {
            let mut rng = Rng::new(1000 + i as u64);
            let n = if i % 2 == 0 { 256 } else { 200 };
            let sparsity = if i % 5 == 4 { 0.5 } else { 0.99 };
            let a = gen::uniform(n, sparsity, &mut rng);
            let b = Mat::randn(n, n, &mut rng);
            SpdmRequest::new(i as u64, a, b)
        })
        .collect()
}

/// The pre-arena request path, replicated faithfully for the A/B: stats
/// scan, pad A to a guessed size, full GCOO build, re-pad to the artifact
/// capacity, clone the slabs (the old `engine.run_gcoo` always cloned),
/// pad B — every step a fresh allocation.
fn baseline_one(engine: &Engine, reg: &Registry, cfg: &CoordinatorConfig, req: &SpdmRequest) -> Mat {
    let req_a = req.a.as_inline().expect("bench workload is inline");
    let n = req_a.rows;
    let pad = |m: &Mat, to: usize| {
        let mut out = Mat::zeros(to, to);
        for i in 0..m.rows {
            out.row_mut(i)[..m.cols].copy_from_slice(m.row(i));
        }
        out
    };
    // old stats scan (sparsity + max row nnz)
    let mut nnz = 0usize;
    let mut max_row = 0usize;
    for i in 0..n {
        let rn = req_a.row(i).iter().filter(|v| **v != 0.0).count();
        nnz += rn;
        max_row = max_row.max(rn);
    }
    let sparsity = 1.0 - nnz as f64 / (n * n) as f64;
    // guess-convert at fit size
    let n_exec_guess = reg.fit_size("gcoo", n).unwrap_or(n);
    let a_pad = pad(req_a, n_exec_guess);
    let (gcoo, _t) = convert::dense_to_gcoo_parallel(&a_pad, cfg.gcoo_p, cfg.convert_threads);
    let selector = Selector::new(cfg.policy);
    let plan = selector
        .plan(reg, n, sparsity, gcoo.max_group_nnz(), max_row, None)
        .expect("baseline plan");
    let b_pad = pad(&req.b, plan.n_exec);
    // re-pad to the artifact capacity, then clone the slabs like the old
    // engine did even at matching cap
    let padded = gcoo.pad(plan.cap.max(gcoo.max_group_nnz())).expect("baseline pad");
    let cloned = GcooPadded {
        g: padded.g,
        cap: padded.cap,
        p: padded.p,
        n: padded.n,
        vals: padded.vals.clone(),
        rows: padded.rows.clone(),
        cols: padded.cols.clone(),
    };
    let out = engine.run_gcoo(reg, &cloned, &b_pad, true).expect("baseline run");
    // old trim always copied
    let mut c = Mat::zeros(n, n);
    for i in 0..n {
        c.row_mut(i).copy_from_slice(&out.c.row(i)[..n]);
    }
    c
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let iters = if quick { 24 } else { 200 };
    let reg = registry();
    let cfg = CoordinatorConfig { workers: 2, ..Default::default() };
    println!("serve_hotpath: {} requests, fixed seeds, quick={quick}", iters);

    // Per-phase results, emitted as BENCH_9.json at the end of the run
    // (machine-readable mirror of the printed lines; ci.sh --quick runs this).
    let mut phases: Vec<Value> = Vec::new();

    // --- Phase 1: process_one through the coordinator (queue + workers) ---
    {
        let coord = Coordinator::new(Arc::new(registry()), cfg);
        let reqs = workload(iters);
        let t0 = Instant::now();
        let receivers: Vec<_> = reqs
            .into_iter()
            .map(|r| coord.submit(r).expect("queue open"))
            .collect();
        for rx in receivers {
            let resp = rx.recv().expect("reply");
            assert!(resp.ok(), "{:?}", resp.error);
        }
        let wall = t0.elapsed().as_secs_f64();
        let snap = coord.metrics().snapshot();
        println!(
            "coordinator: {:.1} req/s  (p50 {:.2} ms, p99 {:.2} ms)",
            iters as f64 / wall,
            snap.p50_s * 1e3,
            snap.p99_s * 1e3
        );
        println!(
            "copy counters: {} B copied, {} allocations/copies avoided",
            snap.bytes_copied, snap.copies_avoided
        );
        phases.push(
            Value::obj()
                .field("phase", "coordinator")
                .field("req_s", iters as f64 / wall)
                .field("p50_ms", snap.p50_s * 1e3)
                .field("p95_ms", snap.p95_s * 1e3)
                .field("p99_ms", snap.p99_s * 1e3)
                .field("bytes_copied", snap.bytes_copied)
                .field("copies_avoided", snap.copies_avoided)
                .field("conversions_total", snap.conversions_total)
                .build(),
        );
        coord.shutdown();
    }

    // --- Phase 2: A/B on the sparse hot path (same seeds both sides) ---
    {
        // Keep only the gcoo-routed requests (n=256, sparsity 0.99): both
        // sides of the A/B then exercise the same algorithm and artifact.
        let sparse: Vec<SpdmRequest> = workload(iters)
            .into_iter()
            .filter(|r| r.a.as_inline().map(|a| a.rows) == Some(256) && r.id % 5 != 4)
            .collect();
        let engine = Engine::new().unwrap();
        let mut ws = Workspace::new();
        // warm the arena + compile cache outside the timers
        for r in sparse.iter().take(2) {
            let _ = process_one_ws(&engine, &mut ws, &reg, &cfg, r, None, Instant::now());
        }
        let t0 = Instant::now();
        for r in &sparse {
            let resp = process_one_ws(&engine, &mut ws, &reg, &cfg, r, None, Instant::now());
            assert!(resp.ok(), "{:?}", resp.error);
        }
        let arena_s = t0.elapsed().as_secs_f64();

        for r in sparse.iter().take(2) {
            let _ = baseline_one(&engine, &reg, &cfg, r);
        }
        let t1 = Instant::now();
        for r in &sparse {
            let _ = baseline_one(&engine, &reg, &cfg, r);
        }
        let base_s = t1.elapsed().as_secs_f64();

        let arena_rps = sparse.len() as f64 / arena_s;
        let base_rps = sparse.len() as f64 / base_s;
        println!(
            "direct sparse path: arena {:.1} req/s | baseline copy-path {:.1} req/s | speedup {:.2}x",
            arena_rps,
            base_rps,
            arena_rps / base_rps
        );
        phases.push(
            Value::obj()
                .field("phase", "sparse_hotpath_ab")
                .field("arena_req_s", arena_rps)
                .field("baseline_req_s", base_rps)
                .field("speedup", arena_rps / base_rps)
                .build(),
        );
    }

    // --- Phase 3: batched vs sequential A/B (shared A, fixed seeds) ---
    // The fused-batch proposition at its cleanest: k requests sharing one A
    // pay one conversion + one wide kernel when fused, k of each when
    // sequential. Both sides run the identical request set; outputs are
    // asserted bitwise identical before timing is reported.
    {
        let count = if quick { 24 } else { 120 };
        let width = cfg.batch_max;
        let engine = Engine::new().unwrap();
        let mut rng = Rng::new(2000);
        let a = gen::uniform(256, 0.99, &mut rng);
        let reqs: Vec<SpdmRequest> = (0..count)
            .map(|i| SpdmRequest::new(i as u64, a.clone(), Mat::randn(256, 256, &mut rng)))
            .collect();

        let mut ws_seq = Workspace::new();
        for r in reqs.iter().take(2) {
            let _ = process_one_ws(&engine, &mut ws_seq, &reg, &cfg, r, None, Instant::now());
        }
        let t0 = Instant::now();
        let seq: Vec<_> = reqs
            .iter()
            .map(|r| process_one_ws(&engine, &mut ws_seq, &reg, &cfg, r, None, Instant::now()))
            .collect();
        let seq_s = t0.elapsed().as_secs_f64();

        let mut ws_bat = Workspace::new();
        {
            let warm: Vec<BatchJob<'_>> =
                reqs.iter().take(width).map(|r| BatchJob::inline(r, Instant::now())).collect();
            let _ = process_batch_ws(&engine, &mut ws_bat, &reg, &cfg, &warm);
        }
        let t1 = Instant::now();
        let mut bat = Vec::with_capacity(count);
        let mut batches = 0u64;
        let mut amortized = 0u64;
        for chunk in reqs.chunks(width) {
            let jobs: Vec<BatchJob<'_>> =
                chunk.iter().map(|r| BatchJob::inline(r, Instant::now())).collect();
            bat.extend(process_batch_ws(&engine, &mut ws_bat, &reg, &cfg, &jobs));
            batches += 1;
            amortized += (chunk.len() - 1) as u64;
        }
        let bat_s = t1.elapsed().as_secs_f64();

        for (s, b) in seq.iter().zip(&bat) {
            assert!(s.ok() && b.ok(), "{:?} / {:?}", s.error, b.error);
            assert!(s.c == b.c, "batched C must be bitwise identical to sequential");
        }
        let seq_rps = count as f64 / seq_s;
        let bat_rps = count as f64 / bat_s;
        println!(
            "batched vs sequential (width {width}): fused {:.1} req/s | sequential {:.1} req/s | speedup {:.2}x",
            bat_rps,
            seq_rps,
            bat_rps / seq_rps
        );
        println!(
            "batched: {count} jobs in {batches} batches, {amortized} conversions amortized ({} per batch at full width)",
            width - 1
        );
        phases.push(
            Value::obj()
                .field("phase", "batched_vs_sequential")
                .field("fused_req_s", bat_rps)
                .field("sequential_req_s", seq_rps)
                .field("speedup", bat_rps / seq_rps)
                .field("batches", batches)
                .field("conversions_amortized", amortized)
                .build(),
        );
    }

    // --- Phase 4: handle vs inline A/B (operand store, fixed seeds) ---
    // The register-once proposition: k requests sharing one A pay one
    // conversion total when A is registered (`put_a` + multiply-by-handle)
    // vs one conversion per request when every request re-ships A inline.
    // Both sides run through the live coordinator with identical operand
    // values; outputs are asserted bitwise identical before reporting.
    {
        let count = if quick { 24 } else { 120 };
        let mut rng = Rng::new(3000);
        let a = gen::uniform(256, 0.99, &mut rng);
        let bs: Vec<Mat> = (0..count).map(|_| Mat::randn(256, 256, &mut rng)).collect();

        // Inline side: its own coordinator so the conversion counters are
        // clean. Synchronous submits → width-1 batches → one conversion
        // per request, the v1 cost model.
        let coord = Coordinator::new(
            Arc::new(registry()),
            CoordinatorConfig { workers: 1, ..Default::default() },
        );
        // warm — its conversion is excluded from the reported count so the
        // printed amortization line covers exactly the timed requests.
        let warm = coord.run_sync(SpdmRequest::new(9999, a.clone(), bs[0].clone()));
        assert!(warm.ok(), "{:?}", warm.error);
        let inline_conv0 = coord.snapshot().conversions_total;
        let t0 = Instant::now();
        let inline: Vec<_> = bs
            .iter()
            .enumerate()
            .map(|(i, b)| coord.run_sync(SpdmRequest::new(i as u64, a.clone(), b.clone())))
            .collect();
        let inline_s = t0.elapsed().as_secs_f64();
        let inline_conversions = coord.snapshot().conversions_total - inline_conv0;
        coord.shutdown();

        // Handle side: register once, multiply by reference.
        let coord = Coordinator::new(
            Arc::new(registry()),
            CoordinatorConfig { workers: 1, ..Default::default() },
        );
        let entry = coord.put_a(a.clone(), None).expect("put_a");
        let warm = coord.run_sync(SpdmRequest::for_handle(9999, entry.handle, bs[0].clone()));
        assert!(warm.ok(), "{:?}", warm.error);
        let t1 = Instant::now();
        let by_handle: Vec<_> = bs
            .iter()
            .enumerate()
            .map(|(i, b)| {
                coord.run_sync(SpdmRequest::for_handle(i as u64, entry.handle, b.clone()))
            })
            .collect();
        let handle_s = t1.elapsed().as_secs_f64();
        let handle_conversions = coord.snapshot().conversions_total;
        coord.shutdown();

        for (i, (l, h)) in inline.iter().zip(&by_handle).enumerate() {
            assert!(l.ok() && h.ok(), "[{i}] {:?} / {:?}", l.error, h.error);
            assert!(l.c == h.c, "[{i}] handle path must be bitwise identical to inline");
        }
        let inline_rps = count as f64 / inline_s;
        let handle_rps = count as f64 / handle_s;
        println!(
            "handle vs inline (operand store): by-handle {:.1} req/s | inline {:.1} req/s | speedup {:.2}x",
            handle_rps,
            inline_rps,
            handle_rps / inline_rps
        );
        println!(
            "EO amortization: {count} requests paid {} conversions by handle (1 at put_a) vs {} inline",
            handle_conversions, inline_conversions
        );
        assert_eq!(
            handle_conversions, 1,
            "handle traffic must convert exactly once (at registration)"
        );
        phases.push(
            Value::obj()
                .field("phase", "handle_vs_inline")
                .field("handle_req_s", handle_rps)
                .field("inline_req_s", inline_rps)
                .field("speedup", handle_rps / inline_rps)
                .field("handle_conversions", handle_conversions)
                .field("inline_conversions", inline_conversions)
                .build(),
        );
    }

    // --- Phase 5: adaptive vs static routing A/B (fixed seeds) ---
    // The tuner's promise: measured routing changes *choices* (provenance,
    // exploration, flips), never *results*. Both sides serve the identical
    // handle workload through live coordinators; outputs are asserted
    // bitwise identical before the adaptive side's req/s and its
    // exploration/flip counters are reported.
    {
        let count = if quick { 24 } else { 120 };
        let mut rng = Rng::new(4000);
        let a = gen::uniform(256, 0.99, &mut rng);
        let bs: Vec<Mat> = (0..count).map(|_| Mat::randn(256, 256, &mut rng)).collect();

        let run_side = |tuning: TunerConfig| {
            let coord = Coordinator::new(
                Arc::new(registry()),
                CoordinatorConfig { workers: 1, tuning, ..Default::default() },
            );
            let entry = coord.put_a(a.clone(), None).expect("put_a");
            let warm = coord.run_sync(SpdmRequest::for_handle(9999, entry.handle, bs[0].clone()));
            assert!(warm.ok(), "{:?}", warm.error);
            let t0 = Instant::now();
            let resps: Vec<_> = bs
                .iter()
                .enumerate()
                .map(|(i, b)| {
                    coord.run_sync(SpdmRequest::for_handle(i as u64, entry.handle, b.clone()))
                })
                .collect();
            let wall = t0.elapsed().as_secs_f64();
            let snap = coord.snapshot();
            coord.shutdown();
            (resps, wall, snap)
        };

        let (stat, stat_s, _) = run_side(TunerConfig::default());
        let (adap, adap_s, snap) = run_side(TunerConfig {
            enabled: true,
            explore_every: 4,
            min_samples: 3,
            register_refine_budget: 2,
            ..Default::default()
        });
        for (i, (s, ad)) in stat.iter().zip(&adap).enumerate() {
            assert!(s.ok() && ad.ok(), "[{i}] {:?} / {:?}", s.error, ad.error);
            assert!(
                s.c == ad.c,
                "[{i}] adaptive routing must be bitwise identical to static"
            );
        }
        println!(
            "adaptive vs static routing: adaptive {:.1} req/s | static {:.1} req/s | ratio {:.2}x",
            count as f64 / adap_s,
            count as f64 / stat_s,
            stat_s / adap_s,
        );
        println!(
            "adaptive side: {} explorations, {} route flips, {} conversions total",
            snap.explorations, snap.route_flips, snap.conversions_total
        );
        phases.push(
            Value::obj()
                .field("phase", "adaptive_vs_static")
                .field("adaptive_req_s", count as f64 / adap_s)
                .field("static_req_s", count as f64 / stat_s)
                .field("ratio", stat_s / adap_s)
                .field("explorations", snap.explorations)
                .field("route_flips", snap.route_flips)
                .field("conversions_total", snap.conversions_total)
                .build(),
        );
    }

    // --- Phase 6: binary v3 vs JSON v2 wire A/B (live TCP, fixed seeds) ---
    // The tentpole proposition measured end to end: identical inline
    // requests through a live server on both planes, checksums asserted
    // bitwise equal, then req/s and bytes-on-wire per request. The server
    // work (decode → convert → kernel) is identical on both sides, so the
    // differential is exactly the wire + parse cost v3 removes.
    {
        let count = if quick { 6 } else { 20 };
        let n = 256usize;
        let coord = Arc::new(Coordinator::new(
            Arc::new(registry()),
            CoordinatorConfig { workers: 1, ..Default::default() },
        ));
        let server = Server::bind(&ServerConfig::ephemeral(), Arc::clone(&coord)).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let _ = server.run();
        });
        let mut client = Client::connect(&addr).unwrap();

        let reqs: Vec<(Mat, Mat)> = (0..count)
            .map(|i| {
                let mut rng = Rng::new(5000 + i as u64);
                (gen::uniform(n, 0.99, &mut rng), Mat::randn(n, n, &mut rng))
            })
            .collect();

        // Warm both planes (compile cache + arena) outside the timers.
        let w = client.spdm_inline(9000, n, &reqs[0].0.data, &reqs[0].1.data, false).unwrap();
        assert!(w.ok, "{:?}", w.error);
        let (w, _) = client
            .spdm_inline_bin(9001, n, &reqs[0].0.data, &reqs[0].1.data, None, false, false)
            .unwrap();
        assert!(w.ok, "{:?}", w.error);

        client.reset_wire_counters();
        let t0 = Instant::now();
        let json_sums: Vec<u64> = reqs
            .iter()
            .enumerate()
            .map(|(i, (a, b))| {
                let r = client.spdm_inline(i as u64, n, &a.data, &b.data, false).unwrap();
                assert!(r.ok, "{:?}", r.error);
                r.checksum.unwrap().to_bits()
            })
            .collect();
        let json_s = t0.elapsed().as_secs_f64();
        let (sent, recv) = client.bytes_on_wire();
        let json_bytes_per_req = (sent + recv) as f64 / count as f64;

        client.reset_wire_counters();
        let t1 = Instant::now();
        let bin_sums: Vec<u64> = reqs
            .iter()
            .enumerate()
            .map(|(i, (a, b))| {
                let (r, _) = client
                    .spdm_inline_bin(1000 + i as u64, n, &a.data, &b.data, None, false, false)
                    .unwrap();
                assert!(r.ok, "{:?}", r.error);
                r.checksum.unwrap().to_bits()
            })
            .collect();
        let bin_s = t1.elapsed().as_secs_f64();
        let (sent, recv) = client.bytes_on_wire();
        let bin_bytes_per_req = (sent + recv) as f64 / count as f64;

        assert_eq!(
            json_sums, bin_sums,
            "binary and JSON planes must produce bitwise-identical checksums"
        );
        let json_rps = count as f64 / json_s;
        let bin_rps = count as f64 / bin_s;
        println!(
            "binary vs JSON wire: binary {:.1} req/s | JSON {:.1} req/s | speedup {:.2}x",
            bin_rps,
            json_rps,
            bin_rps / json_rps
        );
        println!(
            "bytes on wire per request: binary {:.0} | JSON {:.0} | {:.1}x smaller",
            bin_bytes_per_req,
            json_bytes_per_req,
            json_bytes_per_req / bin_bytes_per_req
        );
        assert!(
            bin_rps >= 2.0 * json_rps,
            "binary plane must be ≥2x JSON on inline traffic (got {:.2}x)",
            bin_rps / json_rps
        );
        phases.push(
            Value::obj()
                .field("phase", "binary_vs_json")
                .field("binary_req_s", bin_rps)
                .field("json_req_s", json_rps)
                .field("speedup", bin_rps / json_rps)
                .field("wire_bytes_per_req_binary", bin_bytes_per_req)
                .field("wire_bytes_per_req_json", json_bytes_per_req)
                .field("wire_shrink", json_bytes_per_req / bin_bytes_per_req)
                .build(),
        );
        client.shutdown(9999).unwrap();
        server.join().unwrap();
    }

    // --- Phase 7: open-loop admission window on vs off (fixed seeds) ---
    // Paced arrivals (gap calibrated to ~2x the measured service time, so
    // the window-off side genuinely drains to width-1 batches), identical
    // handle workload both sides, results asserted bitwise equal; the
    // window side must achieve a strictly wider mean fused-batch width.
    {
        let count = if quick { 16 } else { 48 };
        let n = 256usize;
        let mut rng = Rng::new(6000);
        let a = gen::uniform(n, 0.99, &mut rng);
        let bs: Vec<Mat> = (0..count).map(|_| Mat::randn(n, n, &mut rng)).collect();

        // Calibrate the arrival gap on a throwaway coordinator: median-ish
        // service time of a warm handle request.
        let gap_us = {
            let coord = Coordinator::new(
                Arc::new(registry()),
                CoordinatorConfig { workers: 1, ..Default::default() },
            );
            let entry = coord.put_a(a.clone(), None).expect("put_a");
            let warm = coord.run_sync(SpdmRequest::for_handle(0, entry.handle, bs[0].clone()));
            assert!(warm.ok(), "{:?}", warm.error);
            let t0 = Instant::now();
            for i in 0..3u64 {
                let r = coord.run_sync(SpdmRequest::for_handle(i, entry.handle, bs[0].clone()));
                assert!(r.ok(), "{:?}", r.error);
            }
            let svc_us = t0.elapsed().as_micros() as u64 / 3;
            coord.shutdown();
            (2 * svc_us).clamp(200, 20_000)
        };
        let window_us = 8 * gap_us;

        let run_open_loop = |admission_window_us: u64| {
            let coord = Coordinator::new(
                Arc::new(registry()),
                CoordinatorConfig {
                    workers: 1,
                    batch_max: 8,
                    admission_window_us,
                    ..Default::default()
                },
            );
            let entry = coord.put_a(a.clone(), None).expect("put_a");
            let warm = coord.run_sync(SpdmRequest::for_handle(9999, entry.handle, bs[0].clone()));
            assert!(warm.ok(), "{:?}", warm.error);
            let mut rxs = Vec::with_capacity(count);
            for (i, b) in bs.iter().enumerate() {
                rxs.push(
                    coord
                        .submit(SpdmRequest::for_handle(i as u64, entry.handle, b.clone()))
                        .expect("queue open"),
                );
                std::thread::sleep(std::time::Duration::from_micros(gap_us));
            }
            let sums: Vec<u64> = rxs
                .into_iter()
                .map(|rx| {
                    let resp = rx.recv().expect("reply");
                    assert!(resp.ok(), "{:?}", resp.error);
                    let c = resp.c.expect("response carries C");
                    let sum: f64 = c.data.iter().map(|x| *x as f64).sum();
                    sum.to_bits()
                })
                .collect();
            let snap = coord.snapshot();
            coord.shutdown();
            (sums, snap)
        };

        let (sums_off, snap_off) = run_open_loop(0);
        let (sums_on, snap_on) = run_open_loop(window_us);
        assert_eq!(sums_off, sums_on, "the admission window must never change results");
        let width_off = snap_off.mean_batch_width();
        let width_on = snap_on.mean_batch_width();
        println!(
            "open-loop admission (gap {gap_us} µs, window {window_us} µs): \
             mean width {:.2} (on) vs {:.2} (off) | window {} filled / {} timed out",
            width_on, width_off, snap_on.window_hits, snap_on.window_timeouts
        );
        println!(
            "open-loop latency: on p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms | \
             off p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms",
            snap_on.p50_s * 1e3,
            snap_on.p95_s * 1e3,
            snap_on.p99_s * 1e3,
            snap_off.p50_s * 1e3,
            snap_off.p95_s * 1e3,
            snap_off.p99_s * 1e3,
        );
        assert!(
            width_on > width_off,
            "the admission window must widen mean fused-batch width under open-loop load \
             ({width_on:.2} vs {width_off:.2})"
        );
        assert_eq!(snap_off.window_hits + snap_off.window_timeouts, 0);
        phases.push(
            Value::obj()
                .field("phase", "open_loop_admission")
                .field("arrival_gap_us", gap_us)
                .field("window_us", window_us)
                .field("mean_width_on", width_on)
                .field("mean_width_off", width_off)
                .field("window_hits", snap_on.window_hits)
                .field("window_timeouts", snap_on.window_timeouts)
                .field("p50_ms_on", snap_on.p50_s * 1e3)
                .field("p95_ms_on", snap_on.p95_s * 1e3)
                .field("p99_ms_on", snap_on.p99_s * 1e3)
                .field("p50_ms_off", snap_off.p50_s * 1e3)
                .field("p95_ms_off", snap_off.p95_s * 1e3)
                .field("p99_ms_off", snap_off.p99_s * 1e3)
                .build(),
        );
    }

    // --- Phase 8: cluster-vs-single wire A/B (router overhead) ----------
    // The same warm handle workload through one plain server and through
    // the 3-node sharded cluster's router: checksums bitwise equal (the
    // cluster's differential obligation, measured here under load), and
    // the req/s ratio is the router's forwarding overhead.
    {
        let count = if quick { 24 } else { 120 };
        let n = 256usize;
        let mut rng = Rng::new(8000);
        let a = gen::uniform(n, 0.99, &mut rng);
        let bs: Vec<Mat> = (0..4).map(|_| Mat::randn(n, n, &mut rng)).collect();

        let run = |addr: &str, label: &str| -> (f64, Vec<u64>) {
            let mut client = Client::connect(addr).unwrap();
            let p = client.put_a_inline(1, n, &a.data, "auto").unwrap();
            assert!(p.ok, "{label}: {:?}", p.error);
            let h = p.a_handle.expect("put_a returns a handle");
            let warm = client.spdm_handle(2, h, &bs[0].data, false).unwrap();
            assert!(warm.ok, "{label}: {:?}", warm.error);
            let t0 = Instant::now();
            let mut sums = Vec::with_capacity(count);
            for i in 0..count {
                let r = client
                    .spdm_handle(10 + i as u64, h, &bs[i % bs.len()].data, false)
                    .unwrap();
                assert!(r.ok, "{label}: {:?}", r.error);
                sums.push(r.checksum.expect("checksum").to_bits());
            }
            (count as f64 / t0.elapsed().as_secs_f64(), sums)
        };

        let coord = Arc::new(Coordinator::new(
            Arc::new(registry()),
            CoordinatorConfig { workers: 1, ..Default::default() },
        ));
        let server = Server::bind(&ServerConfig::ephemeral(), Arc::clone(&coord)).unwrap();
        let saddr = server.local_addr().unwrap().to_string();
        let sthread = std::thread::spawn(move || {
            let _ = server.run();
        });
        let (rps_single, sums_single) = run(&saddr, "single");
        Client::connect(&saddr).unwrap().shutdown(9_999).unwrap();
        sthread.join().unwrap();

        let mut cluster = Cluster::start(
            &ClusterConfig {
                nodes: 3,
                node_cfg: CoordinatorConfig { workers: 1, ..Default::default() },
                ..Default::default()
            },
            Arc::new(registry()),
        )
        .expect("cluster starts");
        let (rps_cluster, sums_cluster) = run(cluster.router_addr(), "cluster");
        assert_eq!(
            sums_single, sums_cluster,
            "the cluster must answer bitwise identically to a single node"
        );
        let agg = cluster.snapshot();
        assert!(agg.store_hits > 0, "handle traffic must serve from the store");
        cluster.shutdown();

        println!(
            "cluster A/B: single {rps_single:.1} req/s vs 3-node routed {rps_cluster:.1} req/s \
             (router overhead x{:.2})",
            rps_single / rps_cluster
        );
        phases.push(
            Value::obj()
                .field("phase", "cluster_vs_single")
                .field("nodes", 3usize)
                .field("requests", count)
                .field("req_per_s_single", rps_single)
                .field("req_per_s_cluster", rps_cluster)
                .field("router_overhead", rps_single / rps_cluster)
                .field("bitwise_identical", true)
                .build(),
        );
    }

    // --- Phase 9: hot-tenant vs weighted-fair A/B (fixed seeds) ---------
    // A flooding tenant submits a burst ahead of a light tenant's handful
    // of requests. Untenanted FIFO drains the flood first; weighted DRR
    // lanes interleave, so the light tenant's last reply lands long before
    // the flood finishes. Checksums are asserted bitwise equal across the
    // two scheduling regimes — fairness changes *order*, never *bits*.
    {
        let heavy_n: usize = if quick { 16 } else { 48 };
        let light_n: usize = if quick { 4 } else { 12 };
        let n = 256usize;
        let mut rng = Rng::new(9000);
        let a_heavy = gen::uniform(n, 0.99, &mut rng);
        let a_light = gen::uniform(n, 0.99, &mut rng);
        let bs_heavy: Vec<Mat> = (0..heavy_n).map(|_| Mat::randn(n, n, &mut rng)).collect();
        let bs_light: Vec<Mat> = (0..light_n).map(|_| Mat::randn(n, n, &mut rng)).collect();

        // Returns (light-drain seconds, total seconds, checksum bits by id).
        let run = |tenants: Vec<TenantSpec>| {
            let tagged = !tenants.is_empty();
            let coord = Coordinator::new(
                Arc::new(registry()),
                CoordinatorConfig { workers: 1, tenants, ..Default::default() },
            );
            let (eh, el) = if tagged {
                (
                    coord.put_a_for("heavy", a_heavy.clone(), None).expect("put_a heavy"),
                    coord.put_a_for("light", a_light.clone(), None).expect("put_a light"),
                )
            } else {
                (
                    coord.put_a(a_heavy.clone(), None).expect("put_a heavy"),
                    coord.put_a(a_light.clone(), None).expect("put_a light"),
                )
            };
            let warm = coord.run_sync(SpdmRequest::for_handle(9999, eh.handle, bs_heavy[0].clone()));
            assert!(warm.ok(), "{:?}", warm.error);
            let t0 = Instant::now();
            let heavy_rxs: Vec<_> = bs_heavy
                .iter()
                .enumerate()
                .map(|(i, b)| {
                    let mut r = SpdmRequest::for_handle(i as u64, eh.handle, b.clone());
                    if tagged {
                        r = r.with_tenant("heavy");
                    }
                    coord.submit(r).expect("queue open")
                })
                .collect();
            let light_rxs: Vec<_> = bs_light
                .iter()
                .enumerate()
                .map(|(i, b)| {
                    let mut r =
                        SpdmRequest::for_handle((1000 + i) as u64, el.handle, b.clone());
                    if tagged {
                        r = r.with_tenant("light");
                    }
                    coord.submit(r).expect("queue open")
                })
                .collect();
            let checksum = |resp: gcoospdm::coordinator::SpdmResponse| {
                assert!(resp.error.is_none(), "{:?}", resp.error);
                let c = resp.c.expect("response carries C");
                let sum: f64 = c.data.iter().map(|x| *x as f64).sum();
                sum.to_bits()
            };
            let light_sums: Vec<u64> =
                light_rxs.into_iter().map(|rx| checksum(rx.recv().expect("reply"))).collect();
            let light_s = t0.elapsed().as_secs_f64();
            let heavy_sums: Vec<u64> =
                heavy_rxs.into_iter().map(|rx| checksum(rx.recv().expect("reply"))).collect();
            let total_s = t0.elapsed().as_secs_f64();
            coord.shutdown();
            (light_s, total_s, heavy_sums, light_sums)
        };

        let (fifo_light_s, fifo_total_s, fifo_heavy, fifo_light) = run(Vec::new());
        let (fair_light_s, fair_total_s, fair_heavy, fair_light) = run(vec![
            TenantSpec { name: "heavy".into(), weight: 1, ..TenantSpec::unlimited("heavy") },
            TenantSpec { name: "light".into(), weight: 4, ..TenantSpec::unlimited("light") },
        ]);
        assert_eq!(fifo_heavy, fair_heavy, "fair scheduling must never change heavy-tenant bits");
        assert_eq!(fifo_light, fair_light, "fair scheduling must never change light-tenant bits");
        println!(
            "hot-tenant vs fair (flood {heavy_n} ahead of {light_n}): light drained in \
             {:.1} ms fair vs {:.1} ms FIFO (totals {:.1} / {:.1} ms)",
            fair_light_s * 1e3,
            fifo_light_s * 1e3,
            fair_total_s * 1e3,
            fifo_total_s * 1e3
        );
        phases.push(
            Value::obj()
                .field("phase", "tenant_fairness")
                .field("flood_requests", heavy_n)
                .field("light_requests", light_n)
                .field("light_drain_ms_fifo", fifo_light_s * 1e3)
                .field("light_drain_ms_fair", fair_light_s * 1e3)
                .field("total_ms_fifo", fifo_total_s * 1e3)
                .field("total_ms_fair", fair_total_s * 1e3)
                .field("bitwise_identical", true)
                .build(),
        );
    }

    // --- Phase 10: spill promote vs inline reconvert (fixed seeds) ------
    // Two operands thrash one tenant's single-entry slice, so every handle
    // request promotes a demoted entry from the disk tier (one sequential
    // slab read, zero reconversion — the counter is pinned). The baseline
    // is what a spill-less server forces on an evicted client: re-ship A
    // inline and pay the conversion again on every request.
    {
        let cycles: usize = if quick { 6 } else { 24 };
        let n = 256usize;
        let mut rng = Rng::new(9500);
        let a1 = gen::uniform(n, 0.99, &mut rng);
        let a2 = gen::uniform(n, 0.99, &mut rng);
        let bs: Vec<Mat> = (0..2).map(|_| Mat::randn(n, n, &mut rng)).collect();

        // Size the slice off real registrations: fits either, never both.
        let (slice, base_sums, reconvert_rps, reconvert_conversions) = {
            let coord = Coordinator::new(
                Arc::new(registry()),
                CoordinatorConfig { workers: 1, ..Default::default() },
            );
            let e1 = coord.put_a(a1.clone(), None).expect("put_a a1");
            let e2 = coord.put_a(a2.clone(), None).expect("put_a a2");
            let slice = (e1.bytes.max(e2.bytes) + e1.bytes + e2.bytes) / 2;
            let warm = coord.run_sync(SpdmRequest::new(9999, a1.clone(), bs[0].clone()));
            assert!(warm.ok(), "{:?}", warm.error);
            let conv0 = coord.snapshot().conversions_total;
            let t0 = Instant::now();
            let mut sums = Vec::new();
            for i in 0..cycles {
                for (k, a) in [&a1, &a2].into_iter().enumerate() {
                    let resp = coord.run_sync(SpdmRequest::new(
                        (i * 2 + k) as u64,
                        a.clone(),
                        bs[k].clone(),
                    ));
                    assert!(resp.ok(), "{:?}", resp.error);
                    let sum: f64 =
                        resp.c.expect("C").data.iter().map(|x| *x as f64).sum();
                    sums.push(sum.to_bits());
                }
            }
            let rps = (cycles * 2) as f64 / t0.elapsed().as_secs_f64();
            let conversions = coord.snapshot().conversions_total - conv0;
            coord.shutdown();
            (slice, sums, rps, conversions)
        };

        let spill_dir = std::env::temp_dir()
            .join(format!("gcoospdm_bench_spill_{}", std::process::id()));
        let coord = Coordinator::new(
            Arc::new(registry()),
            CoordinatorConfig {
                workers: 1,
                tenants: vec![TenantSpec {
                    store_slice_bytes: slice,
                    ..TenantSpec::unlimited("solo")
                }],
                spill_dir: Some(spill_dir.clone()),
                ..Default::default()
            },
        );
        let e1 = coord.put_a_for("solo", a1.clone(), None).expect("put_a a1");
        let e2 = coord.put_a_for("solo", a2.clone(), None).expect("put_a a2");
        let handles = [e1.handle, e2.handle];
        let conv0 = coord.snapshot().conversions_total;
        let t0 = Instant::now();
        let mut promote_sums = Vec::new();
        for i in 0..cycles {
            for k in 0..2usize {
                // Each request targets the currently-demoted operand: one
                // promote (and one displacement) per request.
                let resp = coord.run_sync(
                    SpdmRequest::for_handle((i * 2 + k) as u64, handles[k], bs[k].clone())
                        .with_tenant("solo"),
                );
                assert!(resp.ok(), "{:?}", resp.error);
                let sum: f64 = resp.c.expect("C").data.iter().map(|x| *x as f64).sum();
                promote_sums.push(sum.to_bits());
            }
        }
        let promote_rps = (cycles * 2) as f64 / t0.elapsed().as_secs_f64();
        assert_eq!(
            coord.snapshot().conversions_total - conv0,
            0,
            "promote cycles must never reconvert"
        );
        let st = coord.store().stats();
        assert_eq!(
            base_sums, promote_sums,
            "spill promotion must serve bitwise-identical results"
        );
        coord.shutdown();
        let _ = std::fs::remove_dir_all(&spill_dir);

        println!(
            "spill promote vs reconvert: promote {promote_rps:.1} req/s | inline reconvert \
             {reconvert_rps:.1} req/s | speedup {:.2}x ({} promotes, {} spill writes, \
             0 vs {} conversions)",
            promote_rps / reconvert_rps,
            st.spill_promotes,
            st.spill_writes,
            reconvert_conversions
        );
        phases.push(
            Value::obj()
                .field("phase", "spill_promote_vs_reconvert")
                .field("promote_req_s", promote_rps)
                .field("reconvert_req_s", reconvert_rps)
                .field("speedup", promote_rps / reconvert_rps)
                .field("spill_writes", st.spill_writes)
                .field("spill_promotes", st.spill_promotes)
                .field("reconvert_conversions", reconvert_conversions)
                .field("promote_conversions", 0u64)
                .field("bitwise_identical", true)
                .build(),
        );
    }

    // --- Phase 11: kernel family A/B (GCOO vs CMRS vs row-split) -------
    // The two new families on their motivating workload: an extreme-skew
    // Zipf-row matrix (one near-dense head row over a long uniform tail).
    // Every family is hinted over the same fixed-seed requests and must
    // produce bitwise-identical C — the timing difference is the whole
    // point; the numbers are what the measured router learns from.
    {
        let count = if quick { 12 } else { 60 };
        let n = 256usize;
        let engine = Engine::new().unwrap();
        let mut rng = Rng::new(11_000);
        let a = gen::generate(gen::Pattern::ZipfRows, n, 0.99, &mut rng);
        let bs: Vec<Mat> = (0..count).map(|_| Mat::randn(n, n, &mut rng)).collect();
        let families = [Algo::Gcoo, Algo::Cmrs, Algo::RowSplit];
        let mut rps = Vec::new();
        let mut reference: Option<Vec<Option<Mat>>> = None;
        for family in families {
            let reqs: Vec<SpdmRequest> = bs
                .iter()
                .enumerate()
                .map(|(i, b)| {
                    let mut r = SpdmRequest::new(i as u64, a.clone(), b.clone());
                    r.algo_hint = Some(family);
                    r
                })
                .collect();
            let mut ws = Workspace::new();
            for r in reqs.iter().take(2) {
                let _ = process_one_ws(&engine, &mut ws, &reg, &cfg, r, None, Instant::now());
            }
            let t0 = Instant::now();
            let resps: Vec<_> = reqs
                .iter()
                .map(|r| process_one_ws(&engine, &mut ws, &reg, &cfg, r, None, Instant::now()))
                .collect();
            let secs = t0.elapsed().as_secs_f64();
            for resp in &resps {
                assert!(resp.ok(), "{:?}", resp.error);
                assert_eq!(resp.algo, family, "the family hint must win");
            }
            let cs: Vec<Option<Mat>> = resps.into_iter().map(|r| r.c).collect();
            match &reference {
                None => reference = Some(cs),
                Some(base) => assert!(
                    *base == cs,
                    "{} C must be bitwise identical to GCOO",
                    family.as_str()
                ),
            }
            rps.push(count as f64 / secs);
        }
        println!(
            "family A/B (zipf_rows n={n}): gcoo {:.1} req/s | cmrs {:.1} req/s | \
             row-split {:.1} req/s (bitwise identical)",
            rps[0], rps[1], rps[2]
        );
        phases.push(
            Value::obj()
                .field("phase", "family_ab")
                .field("pattern", "zipf_rows")
                .field("gcoo_req_s", rps[0])
                .field("cmrs_req_s", rps[1])
                .field("rowsplit_req_s", rps[2])
                .field("bitwise_identical", true)
                .build(),
        );
    }

    // --- Emit BENCH_10.json --------------------------------------------
    // cwd under `cargo bench` (and ci.sh) is the crate root `rust/`, so the
    // default lands next to the repo-level BENCH files. Override with
    // BENCH_JSON=/path to redirect. The "provenance" stamp is what
    // separates a measured document from the checked-in placeholder.
    let path = std::env::var("BENCH_JSON").unwrap_or_else(|_| "../BENCH_10.json".to_string());
    let doc = Value::obj()
        .field("bench", "serve_hotpath")
        .field("generated", true)
        .field("provenance", "measured")
        .field("quick", quick)
        .field("requests", iters)
        .field("phases", Value::Arr(phases))
        .build();
    match std::fs::write(&path, json::write(&doc)) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("warning: could not write {path}: {e}"),
    }
}
