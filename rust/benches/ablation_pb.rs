//! Ablation bench: the (p, b) design space of GCOOSpDM (DESIGN.md §Perf,
//! paper §VI future work) — simulated kernel time across band heights and
//! block widths, per structural family, plus the autotuner's pick.

use gcoospdm::autotune::{analytic_cost, Autotuner, MatrixStats, B_CANDIDATES, P_CANDIDATES};
use gcoospdm::bench::Table;
use gcoospdm::gen;
use gcoospdm::rng::Rng;
use gcoospdm::simgpu::{self, GcooStructure, WalkConfig, TITANX};
use gcoospdm::sparse::Gcoo;

fn main() {
    let n = 1024;
    let mut t = Table::new(
        "Ablation: simulated GCOO time (µs, TitanX) across (p, b) per structure",
        &["pattern", "sparsity", "p", "b", "sim_us", "analytic_rank"],
    );
    let mut picks = Table::new(
        "Autotuner picks vs exhaustive best",
        &["pattern", "sparsity", "picked_p", "picked_b", "best_p", "best_b", "pick_within_pct"],
    );

    for &(pattern, s) in &[
        (gen::Pattern::Uniform, 0.99),
        (gen::Pattern::Uniform, 0.98),
        (gen::Pattern::DenseColumns, 0.99),
        (gen::Pattern::Diagonal, 0.99),
        (gen::Pattern::PowerLawRows, 0.99),
    ] {
        let mut rng = Rng::new(0xAB1A);
        let a = gen::generate(pattern, n, s, &mut rng);
        let base = Gcoo::from_dense(&a, 8);
        let stats = MatrixStats::measure(&base);
        let tuner = Autotuner::new(&TITANX);
        let ranked = tuner.rank(&stats);

        let mut best: Option<(usize, usize, f64)> = None;
        for &p in &P_CANDIDATES {
            let rebanded = Gcoo::from_csr(
                &gcoospdm::sparse::Csr::from_dense(&a),
                p,
            );
            let st = GcooStructure::new(&rebanded);
            for &b in &B_CANDIDATES {
                let cfg = WalkConfig { b, sample_blocks: 32, seed: 3 };
                let rep = simgpu::simulate_gcoo(&st, &TITANX, &cfg, true);
                let us = rep.time_s() * 1e6;
                let rank = ranked
                    .iter()
                    .position(|c| c.p == p && c.b == b)
                    .map(|i| (i + 1).to_string())
                    .unwrap_or_default();
                t.row(&[
                    pattern.name().into(),
                    format!("{s}"),
                    p.to_string(),
                    b.to_string(),
                    format!("{us:.2}"),
                    rank,
                ]);
                if best.map_or(true, |(_, _, t0)| us < t0) {
                    best = Some((p, b, us));
                }
                // analytic model consistency (ranking is advisory)
                let _ = analytic_cost(&stats, p, b);
            }
        }
        let mut tuner = Autotuner::new(&TITANX);
        let choice = tuner.tune(&base);
        let (bp, bb, bt) = best.unwrap();
        let picked_t = choice.measured_s.unwrap_or(f64::INFINITY) * 1e6;
        picks.row(&[
            pattern.name().into(),
            format!("{s}"),
            choice.p.to_string(),
            choice.b.to_string(),
            bp.to_string(),
            bb.to_string(),
            format!("{:.0}%", 100.0 * (picked_t / bt - 1.0).max(0.0)),
        ]);
    }
    println!("{}", t.render());
    println!("{}", picks.render());
    t.write_csv("results/ablation_pb.csv");
    picks.write_csv("results/ablation_picks.csv");
}
