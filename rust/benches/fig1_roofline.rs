//! Bench: regenerate paper Fig 1 (roofline model + measured dense GEMM).
fn main() {
    gcoospdm::figures::fig1_roofline().print();
}
