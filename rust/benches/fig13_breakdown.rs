//! Bench: regenerate paper Fig 13 (EO vs KC time breakdown).
//!
//! KC times are produced by traced kernel execution — the kernels' memory
//! event streams replayed through the device model (DESIGN.md §Tracing) —
//! not by a separate hand-maintained walker.
fn main() {
    gcoospdm::figures::fig13_breakdown().print();
}
