//! Bench: regenerate paper Fig 13 (EO vs KC time breakdown).
fn main() {
    gcoospdm::figures::fig13_breakdown().print();
}
