//! Bench: measured CPU wall-clock of the real AOT kernels via PJRT —
//! this testbed's analog of the paper's kernel-time comparisons, honestly
//! labeled (interpret-mode Pallas on CPU measures algorithm structure, not
//! GPU performance; see EXPERIMENTS.md).
//!
//! Per (n, sparsity): GCOOSpDM vs GCOO-noreuse (ablation) vs CSR vs
//! dense_xla (vendor GEMM) vs dense_pallas, plus EO (conversion) split.

use gcoospdm::bench::{Bencher, Table};
use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::{Engine, Registry};
use gcoospdm::sparse::{Csr, Ell, Gcoo};

fn main() {
    let reg = match Registry::load("artifacts") {
        Ok(r) => r,
        Err(e) => {
            eprintln!("cpu_wallclock: {e}; run `make artifacts`");
            return;
        }
    };
    let engine = Engine::new().expect("PJRT CPU client");
    println!("PJRT platform: {}", engine.platform());

    let mut t = Table::new(
        "Measured CPU wall-clock per kernel (median of repeated runs, ms)",
        &["n", "sparsity", "gcoo", "gcoo_noreuse", "csr", "dense_xla", "dense_pallas", "convert_eo"],
    );
    let bencher = Bencher::quick();

    for &(n, s) in &[
        (256usize, 0.98f64),
        (256, 0.995),
        (512, 0.98),
        (512, 0.995),
        (1024, 0.995),
    ] {
        let mut rng = Rng::new(0xCA11 ^ n as u64);
        let a = gen::uniform(n, s, &mut rng);
        let b = Mat::randn(n, n, &mut rng);

        let t_conv = std::time::Instant::now();
        let gcoo = Gcoo::from_dense(&a, 8);
        let padded = gcoo.pad(reg.select("gcoo", n, gcoo.max_group_nnz()).unwrap().param("cap").unwrap()).unwrap();
        let csr = Csr::from_dense(&a);
        let rowcap = reg.select("csr", n, csr.max_row_nnz()).unwrap().param("rowcap").unwrap();
        let ell = Ell::from_csr(&csr, rowcap).unwrap();
        let convert_ms = t_conv.elapsed().as_secs_f64() * 1e3;

        let g = bencher.run(|| engine.run_gcoo(&reg, &padded, &b, true).unwrap());
        let gn = bencher.run(|| engine.run_gcoo(&reg, &padded, &b, false).unwrap());
        let c = bencher.run(|| engine.run_csr(&reg, &ell, &b).unwrap());
        let dx = bencher.run(|| engine.run_dense(&reg, "dense_xla", &a, &b).unwrap());
        let dp = bencher.run(|| engine.run_dense(&reg, "dense_pallas", &a, &b).unwrap());

        t.row(&[
            n.to_string(),
            format!("{s}"),
            format!("{:.3}", g.median() * 1e3),
            format!("{:.3}", gn.median() * 1e3),
            format!("{:.3}", c.median() * 1e3),
            format!("{:.3}", dx.median() * 1e3),
            format!("{:.3}", dp.median() * 1e3),
            format!("{:.3}", convert_ms),
        ]);
    }
    println!("{}", t.render());
    t.write_csv("results/cpu_wallclock.csv");
    println!("CSV written to results/cpu_wallclock.csv");
}
