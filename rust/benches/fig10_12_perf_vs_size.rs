//! Bench: regenerate paper Figs 10-12 (effective GFLOPS vs n at s ∈ {0.98, 0.995}).
fn main() {
    gcoospdm::figures::fig10_12_perf_vs_size().print();
}
