//! Bench: regenerate paper Fig 14 (transaction distributions vs n and s).
fn main() {
    gcoospdm::figures::fig14_instructions().print();
}
