//! Bench: regenerate paper Fig 14 (transaction distributions vs n and s).
//!
//! Counters come from traced kernel execution replayed through the device
//! model (DESIGN.md §Tracing); each table also carries per-class transaction
//! shares and a dense-vs-gcoo DRAM supplement across the Table II devices.
fn main() {
    gcoospdm::figures::fig14_instructions().print();
}
