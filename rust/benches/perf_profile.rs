//! §Perf harness: micro-profiles of the L3 hot paths (walkers, conversion,
//! queue, selector) — the before/after numbers in EXPERIMENTS.md §Perf.

use std::time::Instant;

use gcoospdm::bench::{black_box, Bencher};
use gcoospdm::coordinator::BoundedQueue;
use gcoospdm::convert;
use gcoospdm::gen;
use gcoospdm::rng::Rng;
use gcoospdm::simgpu::{self, SyntheticUniform, WalkConfig, TITANX};

fn main() {
    let b = Bencher::default();

    // --- simgpu walkers (the figure benches' dominant cost) ---
    for (n, s) in [(4000usize, 0.9f64), (4000, 0.995), (14000, 0.995)] {
        let st = SyntheticUniform::new(n, s, 8, 7);
        let cfg = WalkConfig::default();
        let g = b.run(|| black_box(simgpu::gcoo_walk(&st, &TITANX, &cfg, true)));
        let c = b.run(|| black_box(simgpu::csr_walk(&st, &TITANX, &cfg)));
        println!(
            "walk n={n} s={s}: gcoo {:.1} ms | csr {:.1} ms (median)",
            g.median() * 1e3,
            c.median() * 1e3
        );
    }
    {
        let cfg = WalkConfig::default();
        let d = b.run(|| black_box(simgpu::gemm_walk(4096, &TITANX, &cfg)));
        println!("walk gemm n=4096: {:.1} ms", d.median() * 1e3);
    }

    // --- dense→GCOO conversion (Algorithm 1) throughput ---
    for n in [1024usize, 2048] {
        let mut rng = Rng::new(1);
        let a = gen::uniform(n, 0.99, &mut rng);
        for threads in [1usize, 4] {
            let t = b.run(|| black_box(convert::dense_to_gcoo_parallel(&a, 8, threads)));
            let gbps = (n * n * 4) as f64 / t.median() / 1e9;
            println!(
                "convert n={n} threads={threads}: {:.2} ms ({gbps:.2} GB/s scan)",
                t.median() * 1e3
            );
        }
    }

    // --- queue throughput (submit/dispatch overhead) ---
    {
        let q: BoundedQueue<(usize, usize)> = BoundedQueue::new(1 << 14);
        let t0 = Instant::now();
        let ops = 200_000usize;
        for i in 0..ops {
            q.try_push((i % 4, i)).unwrap();
            if i % 8 == 7 {
                black_box(q.pop_batch(8, |h, c| h.0 == c.0));
            }
        }
        while q.pop_batch(64, |_, _| true).is_some() {
            if q.is_empty() {
                break;
            }
        }
        let per_op = t0.elapsed().as_secs_f64() / ops as f64;
        println!("queue: {:.0} ns/op (push + amortized batch-pop)", per_op * 1e9);
    }

    // --- selector planning latency ---
    {
        use gcoospdm::coordinator::{Selector, SelectorPolicy};
        use gcoospdm::runtime::Registry;
        if let Ok(reg) = Registry::load("artifacts") {
            let sel = Selector::new(SelectorPolicy::default());
            let t = b.run(|| black_box(sel.plan(&reg, 512, 0.99, 100, 50, None).unwrap()));
            println!("selector plan: {:.2} µs", t.median() * 1e6);
        }
    }
}
