//! Bench: regenerate paper Table I (format memory consumption).
fn main() {
    gcoospdm::figures::table1_memory().print();
}
