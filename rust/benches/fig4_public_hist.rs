//! Bench: regenerate paper Fig 4 (public-corpus speedup histogram, 3 GPUs).
//! Scale via env: FIG4_COUNT (default 300), FIG4_MAX_N (default 1536).
fn main() {
    let count = std::env::var("FIG4_COUNT").ok().and_then(|v| v.parse().ok()).unwrap_or(300);
    let max_n = std::env::var("FIG4_MAX_N").ok().and_then(|v| v.parse().ok()).unwrap_or(1536);
    gcoospdm::figures::fig4_public_hist(count, max_n).print();
}
