//! Bench: regenerate paper Fig 15 (kernel-time scaling vs n and s).
fn main() {
    gcoospdm::figures::fig15_scaling().print();
}
